"""The hostile fleet: chaos injection, self-stabilization, wrap safety.

Four fronts, matching the chaos harness's claims:

- **replayability** — the fault schedule is a pure function of
  (seed, round, phase, peer, op): two transports with the same seed
  inject identical faults, different seeds diverge;
- **survival** — chaos-enabled ``run_gossip_sim`` (drops, duplicates,
  reorders, truncations, damaged frames, mid-session crash, healing
  partition) converges to identical rows with ZERO false negatives,
  and the run leaves a bit-for-bit replayable audit trail;
- **self-stabilization** — a corrupted registry row is detected by the
  CRC integrity check, quarantined, and repaired via gossip re-pull;
- **wraparound** — near-INT32_MAX bases ride the exact promoted rim
  (never the packed kernels), and compare/merge/union stay correct
  across the int32 wrap (bounded-counter semantics).

Plus the socket-liveness regression: a peer that accepts a connection
and then stalls (or trickles) MID-FRAME lands in
``GossipReport.unreachable`` within ~one timeout — it can no longer pin
the session by resetting the per-recv clock on every byte.
"""
import socket as pysock
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.causal import CausalPolicy
from repro.core import clock as bc
from repro.core import wire
from repro.core.sim import SimConfig, run_gossip_sim
from repro.fleet import ClockRegistry, GossipConfig
from repro.fleet import registry as fr
from repro.fleet import transport as ft
from repro.fleet.chaos import (
    ChaosConfig,
    ChaosTransport,
    corrupt_registry_row,
)
from repro.fleet.transport.base import Transport
from repro.obs import AuditTrail, Observer

INT32_MAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# deterministic, replayable fault schedules
# ---------------------------------------------------------------------------

class _ScriptedInner(Transport):
    """Minimal non-authoritative fabric: fixed peers, fixed frames."""

    name = "scripted"
    authoritative = False

    def __init__(self, m: int = 16, n: int = 4):
        super().__init__()
        self.m = m
        self.rows = {
            f"p{i}": np.arange(m, dtype=np.int64) + i for i in range(n)}

    def digests(self):
        self._begin_round()
        digs = {pid: wire.digest_of(pid, row)
                for pid, row in self.rows.items()}
        return digs, 8 * len(digs)

    def pull(self, peer_ids):
        frames = {}
        for pid in peer_ids:
            if pid in self.unreachable:
                continue
            frames[pid] = wire.encode_clock(
                bc.to_wire(bc.BloomClock(
                    jnp.asarray(self.rows[pid], jnp.int32),
                    jnp.zeros((), jnp.int32), 3)))
        return frames, sum(len(f) for f in frames.values())

    def push(self, peer_ids, frame):
        return len(frame) * len(peer_ids)


_HOT_CFG = ChaosConfig(
    seed=13, p_drop_digest=0.3, p_drop_frame=0.4, p_duplicate=0.5,
    p_delay=0.3, p_reorder=0.6, p_truncate=0.3, p_bitflip=0.3,
    p_drop_push=0.4, crashes=(("p1", 2, 2),),
    partitions=((("p2",), 1, 3),))


def _run_schedule(cfg: ChaosConfig, rounds: int = 6):
    tp = ChaosTransport(_ScriptedInner(), cfg)
    outputs = []
    for _ in range(rounds):
        digs, _ = tp.digests()
        frames, _ = tp.pull(sorted(digs))
        tp.push(sorted(digs), b"x" * 40)
        outputs.append((sorted(digs), sorted(frames),
                        sorted(tp.unreachable)))
    return [ev.as_tuple() for ev in tp.schedule], outputs


def test_chaos_schedule_is_seed_deterministic():
    """Same seed -> bit-identical fault schedule AND identical realized
    deliveries; a different seed diverges.  A failing chaos run is a
    repro, not an anecdote."""
    sched_a, out_a = _run_schedule(_HOT_CFG)
    sched_b, out_b = _run_schedule(_HOT_CFG)
    assert sched_a == sched_b
    assert out_a == out_b
    assert sched_a, "hot config injected nothing"
    import dataclasses
    sched_c, _ = _run_schedule(dataclasses.replace(_HOT_CFG, seed=14))
    assert sched_a != sched_c


def test_chaos_injects_every_fault_class():
    sched, _ = _run_schedule(_HOT_CFG, rounds=10)
    kinds = {ev[3] for ev in sched}
    for want in ("drop_digest", "drop_frame", "duplicate", "redeliver",
                 "delay", "reorder", "truncate", "peer_down", "drop_push"):
        assert want in kinds, (want, sorted(kinds))
    # bitflip competes with truncate (elif): assert it fires on its own
    flips, _ = _run_schedule(ChaosConfig(seed=1, p_bitflip=0.9), rounds=4)
    assert {ev[3] for ev in flips} == {"bitflip"}


def test_chaos_quiesce_stops_everything():
    tp = ChaosTransport(_ScriptedInner(), _HOT_CFG)
    tp.digests()
    tp.quiesce()
    before = len(tp.schedule)
    for _ in range(4):
        digs, _ = tp.digests()
        frames, _ = tp.pull(sorted(digs))
        assert sorted(digs) == sorted(tp.inner.rows)   # crash healed too
        assert sorted(frames) == sorted(digs)
        assert not tp.unreachable
    assert len(tp.schedule) == before


# ---------------------------------------------------------------------------
# survival: the full hostile sim
# ---------------------------------------------------------------------------

def test_hostile_socket_fleet_converges_with_zero_false_negatives():
    """The acceptance scenario: drops + duplicates + reorders +
    truncations + bit-flips + a mid-session crash + a corrupted registry
    row, over REAL TCP — and still: no §3 violation, full convergence,
    corruption repaired via gossip, trail replayable bit-for-bit."""
    obs = Observer(audit=AuditTrail(store_frames=True))
    chaos = ChaosConfig(
        seed=7, p_drop_digest=0.1, p_drop_frame=0.15, p_duplicate=0.2,
        p_delay=0.1, p_reorder=0.3, p_truncate=0.1, p_bitflip=0.1,
        p_drop_push=0.1, crashes=(("n4", 2, 2),))
    res = run_gossip_sim(
        SimConfig(n_nodes=5, n_events=150, m=64, k=3, seed=7),
        n_rounds=6,
        gossip_cfg=GossipConfig(policy=CausalPolicy(fp_threshold=1.0),
                                straggler_gap=np.inf, observer=obs,
                                merge_forked=True),
        transport="socket", chaos=chaos, corrupt_at=(3, 1))
    assert res.false_negatives == 0, res.summary()
    assert res.converged, res.summary()
    assert res.fault_events > 0 and res.rejected_frames > 0
    assert res.corrupted >= 1 and res.repaired >= 1

    # satellite: the verdict trail replays bit-for-bit and carries the
    # realized fault schedule + frame ingest order
    kinds = {r.kind for r in obs.audit.records}
    assert {"chaos", "frame_ingest", "frame_rejected",
            "row_corrupt", "row_repaired", "verdict"} <= kinds
    assert obs.audit.chaos_events() and obs.audit.frame_sequence()
    rep = obs.audit.replay_frames()
    assert rep.ok, rep.summary()


def test_hostile_sim_is_reproducible():
    """Two identical seeded runs produce the same verdicts, faults, and
    audit event stream — a failing chaos verdict can be replayed."""
    def run():
        obs = Observer(audit=AuditTrail())
        res = run_gossip_sim(
            SimConfig(n_nodes=5, n_events=120, m=64, k=3, seed=9),
            n_rounds=5,
            gossip_cfg=GossipConfig(policy=CausalPolicy(fp_threshold=1.0),
                                    straggler_gap=np.inf, observer=obs,
                                    merge_forked=True),
            transport="socket",
            chaos=ChaosConfig(seed=5, p_drop_frame=0.2, p_bitflip=0.2,
                              p_duplicate=0.2))
        events = [(r.kind, r.peer_id, r.action, r.verdict, r.detail)
                  for r in obs.audit.records]
        return res.summary(), events

    (sum_a, ev_a), (sum_b, ev_b) = run(), run()
    assert sum_a == sum_b
    assert ev_a == ev_b


def test_partition_heals_and_fleet_reconverges():
    res = run_gossip_sim(
        SimConfig(n_nodes=5, n_events=120, m=64, k=3, seed=3),
        n_rounds=6, transport="socket",
        chaos=ChaosConfig(seed=3, p_drop_frame=0.1, p_duplicate=0.15,
                          partitions=((("n2", "n3"), 1, 4),)))
    assert res.false_negatives == 0 and res.converged, res.summary()


def test_chaos_over_authoritative_loopback():
    res = run_gossip_sim(
        SimConfig(n_nodes=6, n_events=120, m=64, k=3, seed=1),
        n_rounds=5, transport="loopback",
        chaos=ChaosConfig(seed=11, p_drop_digest=0.3, crashes=((2, 1, 2),)))
    assert res.false_negatives == 0 and res.converged, res.summary()
    assert res.transport == "chaos+loopback"


# ---------------------------------------------------------------------------
# self-stabilization: detect, quarantine, repair
# ---------------------------------------------------------------------------

def _clock(cells, k=3):
    return bc.BloomClock(jnp.asarray(cells, jnp.int32),
                         jnp.zeros((), jnp.int32), k)


def test_registry_integrity_detects_quarantines_and_revives():
    reg = ClockRegistry(capacity=8, m=16, k=3)
    rng = np.random.default_rng(0)
    rows = {f"p{i}": rng.integers(0, 40, 16) for i in range(3)}
    reg.admit_many({pid: _clock(r) for pid, r in rows.items()})
    assert reg.check_integrity() == []

    corrupt_registry_row(reg, "p1", seed=0)
    assert reg.check_integrity() == ["p1"]
    reg.quarantine_rows(["p1"])
    assert not reg.row_alive("p1") and "p1" in reg   # dead, slot kept
    view = reg.classify_all(_clock(np.zeros(16)))
    assert not bool(view.alive[reg.slot_of("p1")])

    # repair: an update (the session's forced re-pull) rewrites the row,
    # revives it, and refreshes the CRC
    reg.update_many({"p1": _clock(rows["p1"])})
    assert reg.row_alive("p1")
    assert reg.check_integrity() == []
    assert (np.asarray(reg.get("p1").logical_cells()) == rows["p1"]).all()


def test_session_repairs_corrupted_row_from_peer():
    """End to end over TCP: corrupt the staging row, run ONE verify_rows
    session, and the row is re-pulled from the peer's server."""
    m, k = 16, 3
    truth = np.arange(m, dtype=np.int64) * 3
    node = ft.ClockNode("peer", m, k)
    node.set_cells(truth)
    server = ft.ClockPeerServer(node).start()
    tp = ft.SocketTransport({"peer": server.address}, timeout=2.0)
    reg = ClockRegistry(capacity=4, m=m, k=k)
    try:
        cfg = GossipConfig(policy=CausalPolicy(fp_threshold=1.0),
                           straggler_gap=np.inf, verify_rows=True)
        _, rep0 = ft.anti_entropy_session(reg, _clock(np.zeros(m)), tp, cfg)
        assert rep0.corrupted == () and "peer" in reg

        corrupt_registry_row(reg, "peer", seed=1)
        _, rep1 = ft.anti_entropy_session(reg, _clock(np.zeros(m)), tp, cfg)
        assert rep1.corrupted == ("peer",)
        assert rep1.repaired == ("peer",)
        assert (np.asarray(reg.get("peer").logical_cells()) == truth).all()
        assert reg.check_integrity() == []
    finally:
        tp.close()
        server.stop()


def test_rejected_frame_skips_peer_not_round():
    """A transport serving one damaged frame: the peer lands on
    ``GossipReport.rejected``, everyone else still merges."""
    class _OneBadFrame(_ScriptedInner):
        def pull(self, peer_ids):
            frames, nbytes = super().pull(peer_ids)
            if "p0" in frames:
                frames["p0"] = frames["p0"][:9]     # truncated mid-header
            return frames, nbytes

    tp = _OneBadFrame()
    reg = ClockRegistry(capacity=8, m=tp.m, k=3)
    merged, report = ft.anti_entropy_session(
        reg, _clock(np.zeros(tp.m)), tp,
        GossipConfig(policy=CausalPolicy(fp_threshold=1.0),
                     straggler_gap=np.inf))
    assert report.rejected == ("p0",)
    assert "p0" not in reg                     # never merged
    for pid in ("p1", "p2", "p3"):
        assert pid in reg
    assert report.n_accepted == 3


def test_duplicate_and_stale_ingest_is_idempotent():
    """§3 merge-on-ingest: re-delivering an OLD frame for a known peer
    never regresses the row (the stale duplicate scenario)."""
    m = 16
    old = np.arange(m, dtype=np.int64)
    new = old + 5
    reg = ClockRegistry(capacity=4, m=m, k=3)
    reg.admit("p", _clock(new))

    class _StaleServer(Transport):
        name = "stale"
        authoritative = False

        def digests(self):
            self._begin_round()
            return {"p": wire.digest_of("p", old)}, 8

        def pull(self, peer_ids):
            f = wire.encode_clock(bc.to_wire(_clock(old)))
            return {"p": f}, len(f)

        def push(self, peer_ids, frame):
            return 0

    ft.anti_entropy_session(reg, _clock(np.zeros(m)), _StaleServer(),
                            GossipConfig(policy=CausalPolicy(
                                fp_threshold=1.0), straggler_gap=np.inf))
    assert (np.asarray(reg.get("p").logical_cells()) == new).all()


# ---------------------------------------------------------------------------
# wraparound-safe compare / merge / union (bounded-counter semantics)
# ---------------------------------------------------------------------------

def _wrapped(cells64):
    """int64 logical values folded onto the int32 two's-complement rim."""
    return (np.asarray(cells64, np.int64) & 0xFFFFFFFF).astype(np.uint32) \
        .view(np.int32)


def test_ordering_survives_int32_wrap():
    lo = np.full(8, INT32_MAX - 2, np.int64)
    hi = lo + 5                                   # crosses the wrap
    a = _clock(_wrapped(lo))
    b = _clock(_wrapped(hi))
    o = bc.ordering(a, b)                         # a ≼ b, not b ≼ a
    assert bool(o.a_le_b) and not bool(o.b_le_a)
    assert not bool(o.concurrent) and not bool(o.equal)
    merged = bc.merge(a, b)
    assert (np.asarray(merged.logical_cells(), np.int64)
            == np.asarray(b.logical_cells(), np.int64)).all()


def test_registry_promotes_near_wrap_rows_and_unions_exactly():
    m = 16
    lo = np.full(m, INT32_MAX - 3, np.int64)
    hi = lo.copy()
    hi[::2] += 6                                  # wraps on even cells
    reg = ClockRegistry(capacity=4, m=m, k=3)
    reg.admit_many({"lo": _clock(_wrapped(lo)), "hi": _clock(_wrapped(hi))})
    # near-wrap bases must ride the exact int32 rim, not the u8 pack
    for pid in ("lo", "hi"):
        assert reg.slot_of(pid) in reg._wide, pid
        got = np.asarray(reg.get(pid).logical_cells(), np.int64)
        want = np.asarray(_wrapped(lo if pid == "lo" else hi), np.int64)
        assert (got == want).all()
    assert reg.check_integrity() == []            # CRC matches wide rows

    # union across the wrap is the exact element-wise max on the circle
    mask = np.zeros(4, bool)
    mask[[reg.slot_of("lo"), reg.slot_of("hi")]] = True
    merged = reg.union(mask, _clock(_wrapped(lo)))
    assert (np.asarray(merged.logical_cells(), np.int64)
            == np.asarray(_wrapped(hi), np.int64)).all()

    # classification agrees with the wrap-safe reference ordering:
    # 'lo' is an ancestor of the wrapped local, 'hi' IS the local
    view = reg.classify_all(_clock(_wrapped(hi)))
    assert int(view.status[reg.slot_of("lo")]) == fr.ANCESTOR
    assert int(view.status[reg.slot_of("hi")]) == fr.SAME
    assert "wide_overlay" in view.engine          # exact rim, not the pack


def test_near_wrap_guard_triggers_on_broadcast_too():
    m = 16
    reg = ClockRegistry(capacity=4, m=m, k=3)
    reg.admit("p", _clock(np.arange(m)))
    assert reg.slot_of("p") not in reg._wide
    mask = np.zeros(4, bool)
    mask[reg.slot_of("p")] = True
    reg.broadcast(mask, _clock(_wrapped(np.full(m, INT32_MAX - 1, np.int64))))
    assert reg.slot_of("p") in reg._wide          # promoted, not packed
    assert reg.check_integrity() == []


# ---------------------------------------------------------------------------
# socket liveness: mid-frame stallers cannot pin a session
# ---------------------------------------------------------------------------

def _hostile_listener(behavior):
    """TCP listener that accepts, reads the request, then misbehaves.
    behavior(conn) runs in the accept loop; errors are swallowed."""
    srv = pysock.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(8)
    srv.settimeout(0.2)
    stop = threading.Event()

    def serve():
        while not stop.is_set():
            try:
                conn, _ = srv.accept()
            except (pysock.timeout, OSError):
                continue
            with conn:
                try:
                    conn.recv(64)
                    behavior(conn, stop)
                except OSError:
                    pass

    th = threading.Thread(target=serve, daemon=True)
    th.start()
    return srv, stop


@pytest.mark.parametrize("mode", ["stall", "trickle"])
def test_midframe_staller_lands_in_unreachable(mode):
    """Satellite regression: a peer that accepts and then stalls (or
    trickles one byte at a time) MID-FRAME must land in
    ``GossipReport.unreachable`` within ~one whole-message deadline —
    per-recv timeouts alone reset on every byte and never fire."""
    def stall(conn, stop):
        conn.sendall(b"\x00\x00")                 # 2 of 6 envelope bytes
        stop.wait(8.0)

    def trickle(conn, stop):
        for byte in b"\x00\x00\x00\x20\x01\x01" + b"\x00" * 32:
            if stop.wait(0.3):
                return
            conn.sendall(bytes([byte]))

    srv, stop = _hostile_listener(stall if mode == "stall" else trickle)
    node = ft.ClockNode("good", 16, 3)
    node.set_cells(np.arange(16))
    server = ft.ClockPeerServer(node).start()
    tp = ft.SocketTransport({"good": server.address,
                             "bad": srv.getsockname()}, timeout=1.0)
    reg = ClockRegistry(capacity=4, m=16, k=3)
    try:
        t0 = time.monotonic()
        _, report = ft.anti_entropy_session(
            reg, _clock(np.zeros(16)), tp,
            GossipConfig(policy=CausalPolicy(fp_threshold=1.0),
                         straggler_gap=np.inf))
        elapsed = time.monotonic() - t0
        assert report.unreachable == ("bad",)
        assert "time" in tp.unreachable["bad"].lower()   # deadline, not hang
        assert "good" in reg and report.n_accepted == 1
        assert elapsed < 5.0, f"session pinned for {elapsed:.1f}s"
    finally:
        stop.set()
        tp.close()
        server.stop()
        srv.close()
