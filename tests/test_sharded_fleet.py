"""Multi-device equivalence harness for the sharded ClockRegistry.

Runs on 8 forced host-platform devices (tests/conftest.py sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax
initializes).  The contract under test is exact: for ANY shard count in
{1, 2, 4, 8}, the shard_map'ed classify_all / all_pairs paths must be
**bit-identical** — flags, Eq. 3 fp values, sums — to the unsharded
packed engines, fleets with dead slots and promoted (wide) rows
included, and the audited gossip sim must keep the paper's §3
zero-false-negative guarantee on a sharded registry.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.causal import CausalPolicy
from repro.core import clock as bc
from repro.core.sim import SimConfig, run_gossip_sim
from repro.fleet import ClockRegistry, GossipConfig, fleet_health, gossip_round
from repro.launch.mesh import make_fleet_mesh
from repro.runtime.clock_runtime import ClockConfig, ClockRuntime

SHARD_COUNTS = (1, 2, 4, 8)
CAP, M, K = 32, 192, 3


def _clock(row) -> bc.BloomClock:
    return bc.BloomClock(jnp.asarray(row, jnp.int32),
                         jnp.zeros((), jnp.int32), K)


def _random_fleet(seed: int, cap: int = CAP, m: int = M):
    """Random peer clocks with per-row offsets (non-uniform §4 bases)."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, 20, (cap, m)) + rng.integers(0, 300, (cap, 1))
    return {f"peer{i}": _clock(rows[i]) for i in range(cap)}


def _filled(peers, mesh=None, cap: int = CAP, m: int = M) -> ClockRegistry:
    reg = ClockRegistry(capacity=cap, m=m, k=K, mesh=mesh)
    reg.admit_many(peers)
    return reg


def _evict_some(reg: ClockRegistry, seed: int, n_evict: int = 5):
    rng = np.random.default_rng(1000 + seed)
    gone = rng.choice(sorted(reg.peer_ids()), size=n_evict, replace=False)
    reg.evict_many(list(gone))


def _assert_views_identical(got, ref):
    np.testing.assert_array_equal(got.status, ref.status)
    np.testing.assert_array_equal(got.alive, ref.alive)
    assert (got.fp == ref.fp).all(), "fp must be bit-identical"
    assert (got.sums == ref.sums).all()
    assert got.local_sum == ref.local_sum


def _assert_pairs_identical(got, ref):
    got, ref = jax.device_get(got), jax.device_get(ref)
    for key in ("a_le_b", "b_le_a", "concurrent"):
        np.testing.assert_array_equal(
            np.asarray(got[key], bool), np.asarray(ref[key], bool), err_msg=key)
    assert (np.asarray(got["fp"]) == np.asarray(ref["fp"])).all(), \
        "fp must be bit-identical"
    for key in ("row_sums", "col_sums"):
        assert (np.asarray(got[key]) == np.asarray(ref[key])).all(), key


@pytest.mark.parametrize("seed", range(4))
def test_classify_all_shard_invariance(host_devices, seed):
    """Property: classify_all flags/fp from 1, 2, 4, 8 shards are
    bit-identical to the unsharded packed engine, dead slots included."""
    peers = _random_fleet(seed)
    local = bc.merge(peers["peer0"], peers["peer3"])
    ref_reg = _filled(peers)
    _evict_some(ref_reg, seed)
    ref = ref_reg.classify_all(local)
    for shards in SHARD_COUNTS:
        reg = _filled(peers, mesh=make_fleet_mesh(shards))
        assert reg.n_shards == shards
        _evict_some(reg, seed)
        _assert_views_identical(reg.classify_all(local), ref)


@pytest.mark.parametrize("seed", range(4))
def test_all_pairs_shard_invariance(host_devices, seed):
    """Property: the block-row ppermute ring reproduces the symmetric
    triangle sweep bit-for-bit at every shard count."""
    peers = _random_fleet(seed)
    ref_reg = _filled(peers)
    _evict_some(ref_reg, seed)
    ref = ref_reg.all_pairs()
    for shards in SHARD_COUNTS:
        reg = _filled(peers, mesh=make_fleet_mesh(shards))
        _evict_some(reg, seed)
        _assert_pairs_identical(reg.all_pairs(), ref)


def test_all_pairs_fully_alive_shard_invariance(host_devices):
    """No dead slots: the sharded path returns the ring result directly
    (no host finalize) and must still match the triangle engine."""
    peers = _random_fleet(99)
    ref = _filled(peers).all_pairs()
    for shards in SHARD_COUNTS:
        got = _filled(peers, mesh=make_fleet_mesh(shards)).all_pairs()
        _assert_pairs_identical(got, ref)


def test_sharded_promoted_rows_classify_and_pairs(host_devices):
    """A promoted (span > u8) row keeps both sharded paths exact: the
    packed bulk runs sharded, the wide handful is overlaid int32."""
    peers = _random_fleet(5)
    wide = np.zeros(M, np.int64)
    wide[::7] = 1000                        # span 1000 >> U8_MAX
    peers["peer7"] = _clock(wide)
    local = bc.merge(peers["peer1"], peers["peer2"])
    ref_reg = _filled(peers)
    assert not ref_reg.packed
    ref_view = ref_reg.classify_all(local)
    ref_pairs = ref_reg.all_pairs()
    for shards in (2, 8):
        reg = _filled(peers, mesh=make_fleet_mesh(shards))
        assert not reg.packed
        _assert_views_identical(reg.classify_all(local), ref_view)
        _assert_pairs_identical(reg.all_pairs(), ref_pairs)


def test_gossip_round_sharded_matches_unsharded(host_devices):
    """One anti-entropy round takes identical decisions on a sharded
    registry and reports the shard count."""
    peers = _random_fleet(11)
    local = peers["peer2"]
    cfg = GossipConfig(policy=CausalPolicy(fp_threshold=1.0), push_back=True)
    m_ref, r_ref = gossip_round(_filled(peers), local, cfg)
    for shards in (2, 4):
        reg = _filled(peers, mesh=make_fleet_mesh(shards))
        m_got, r_got = gossip_round(reg, local, cfg)
        np.testing.assert_array_equal(r_got.accepted, r_ref.accepted)
        np.testing.assert_array_equal(r_got.quarantined, r_ref.quarantined)
        np.testing.assert_array_equal(r_got.stragglers, r_ref.stragglers)
        assert r_got.pushback_bytes == r_ref.pushback_bytes
        assert r_got.shards == shards and r_ref.shards == 1
        np.testing.assert_array_equal(
            np.asarray(m_got.logical_cells()), np.asarray(m_ref.logical_cells()))


def test_fleet_health_sharded_matches(host_devices):
    peers = _random_fleet(13)
    ref = fleet_health(_filled(peers))
    got = fleet_health(_filled(peers, mesh=make_fleet_mesh(4)))
    assert got.n_alive == ref.n_alive
    assert got.n_components == ref.n_components
    assert got.comparable_fraction == ref.comparable_fraction
    np.testing.assert_array_equal(got.component, ref.component)
    np.testing.assert_array_equal(got.fp_hist, ref.fp_hist)
    assert got.mean_strict_fp == ref.mean_strict_fp
    assert got.shards == 4 and ref.shards == 1
    assert "shards=4" in got.summary()
    # engine hints that are valid unsharded stay valid sharded (the ring
    # resolves them to its rectangle engine instead of raising)
    hinted = fleet_health(_filled(peers, mesh=make_fleet_mesh(2)),
                          engine="tri")
    assert hinted.n_components == ref.n_components


def test_engine_i32_hint_survives_every_path(host_devices):
    """engine="i32" — the hint the legacy int32 fallback honored —
    keeps working everywhere: fully packed, promoted rows, sharded."""
    packed = _random_fleet(31)
    promoted = dict(packed)
    wide = np.zeros(M, np.int64)
    wide[4] = 3000
    promoted["peer9"] = _clock(wide)
    for peers in (packed, promoted):
        ref = _filled(peers).all_pairs()
        for mesh in (None, make_fleet_mesh(4)):
            got = _filled(peers, mesh=mesh).all_pairs(engine="i32")
            _assert_pairs_identical(got, ref)


@pytest.mark.parametrize("shards", (2, 8))
def test_gossip_sim_sharded_zero_false_negatives(host_devices, shards):
    """§3 on a sharded registry: the audited sim must never call a
    truth-ordered peer FORKED, at any shard count."""
    factory = lambda cap, m, k: ClockRegistry(
        capacity=cap, m=m, k=k, mesh=make_fleet_mesh(shards))
    res = run_gossip_sim(SimConfig(n_nodes=8, n_events=240, m=64, k=3,
                                   seed=3), n_rounds=5,
                         registry_factory=factory)
    assert res.false_negatives == 0
    assert res.rounds == 5 and res.claims > 0
    assert res.within_eq3_band


def test_runtime_make_registry_sharded(host_devices):
    """ClockRuntime builds a mesh-backed registry sized to its config."""
    rt = ClockRuntime(ClockConfig(m=M, k=K))
    reg = rt.make_registry(CAP, mesh=make_fleet_mesh(4))
    assert (reg.m, reg.k, reg.n_shards) == (M, K, 4)
    reg.admit_many(_random_fleet(17))
    view = rt.classify_fleet(reg)
    assert view.alive.all()


def test_registry_capacity_must_divide_shards(host_devices):
    with pytest.raises(ValueError, match="not divisible"):
        ClockRegistry(capacity=30, m=M, k=K, mesh=make_fleet_mesh(4))


# ---------------------------------------------------------------------------
# wire round-trips across shard boundaries
# ---------------------------------------------------------------------------

def _wire_roundtrip(src: ClockRegistry, dst: ClockRegistry):
    """Snapshot every peer of ``src`` in §4 wire form, re-admit into
    ``dst``, and check the logical cells survive losslessly."""
    snaps = {pid: bc.to_wire(src.get(pid)) for pid in src.peer_ids()}
    dst.admit_many({pid: bc.from_wire(s) for pid, s in snaps.items()})
    for pid in src.peer_ids():
        np.testing.assert_array_equal(
            np.asarray(src.get(pid).logical_cells()),
            np.asarray(dst.get(pid).logical_cells()), err_msg=pid)


def test_wire_roundtrip_sharded_to_unsharded(host_devices):
    src = _filled(_random_fleet(21), mesh=make_fleet_mesh(4))
    _wire_roundtrip(src, ClockRegistry(capacity=CAP, m=M, k=K))


def test_wire_roundtrip_unsharded_to_sharded(host_devices):
    src = _filled(_random_fleet(22))
    _wire_roundtrip(src, ClockRegistry(capacity=CAP, m=M, k=K,
                                       mesh=make_fleet_mesh(8)))


def test_wire_roundtrip_across_shard_counts_with_wide_row(host_devices):
    """Promoted rows cross shard boundaries too: wire form falls back to
    int32 cells for them and re-admission preserves them exactly."""
    peers = _random_fleet(23)
    wide = np.zeros(M, np.int64)
    wide[3] = 5000
    peers["peer5"] = _clock(wide)
    src = _filled(peers, mesh=make_fleet_mesh(2))
    dst = ClockRegistry(capacity=CAP, m=M, k=K, mesh=make_fleet_mesh(8))
    _wire_roundtrip(src, dst)
    assert not dst.packed                   # the wide row stayed promoted
