"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU, asserting shapes + finiteness; plus prefill/
decode equivalence against the teacher-forced path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, all_configs, get_config, get_smoke_config
from repro.models import transformer as T
from repro.models.config import validate
from repro.models.params import init_params, param_table
from repro.optim.adamw import OptConfig
from repro.runtime.clock_runtime import ClockConfig
from repro.runtime.training import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.is_encdec:
        kw["enc_frames"] = jax.random.normal(KEY, (B, cfg.enc_seq, cfg.d_model))
    if cfg.n_prefix:
        kw["prefix_embeds"] = jax.random.normal(KEY, (B, cfg.n_prefix, cfg.d_model))
    return tokens, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg)
    tokens, kw = _inputs(cfg)
    logits, aux = T.forward_train(params, cfg, tokens, **kw)
    V = cfg.vocab_pad or cfg.vocab
    S_out = tokens.shape[1] + cfg.n_prefix
    assert logits.shape == (2, S_out, V)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    opt_cfg = OptConfig(lr=1e-3, total_steps=10)
    clock_cfg = ClockConfig(m=64)
    state = init_train_state(KEY, cfg, opt_cfg, clock_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, clock_cfg))
    tokens, kw = _inputs(cfg)
    batch = {"tokens": tokens, "labels": tokens,
             "ev_hi": jnp.uint32(0), "ev_lo": jnp.uint32(1), **kw}
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # the clock ticked k cells
    assert float(jnp.sum(state2.clock_cells)) == clock_cfg.k
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(state.params[k]), np.asarray(state2.params[k]))
        for k in list(state.params)[:5]
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_equivalence(arch):
    """Decode with cache == teacher-forced logits (fp32, no capacity drops)."""
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = init_params(KEY, cfg)
    B, S = 2, 12
    tokens, kw = _inputs(cfg, B, S)
    logits_full, _ = T.forward_train(params, cfg, tokens, **kw)
    logits_pre, caches = T.prefill(params, cfg, tokens[:, :-1], **kw)
    off = cfg.n_prefix
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, off + S - 2]),
                               rtol=2e-4, atol=2e-4)
    logits_dec, _ = T.decode_step(params, cfg, caches, tokens[:, -1],
                                  jnp.asarray(off + S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, off + S - 1]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_table(arch):
    """The FULL assigned config's param table is well-formed (no alloc)."""
    cfg = get_config(arch)
    validate(cfg)
    table = param_table(cfg)
    n = cfg.n_params()
    expected_order = {
        "stablelm_1_6b": (1.2e9, 2.5e9),
        "qwen1_5_0_5b": (3e8, 8e8),
        "qwen1_5_110b": (0.9e11, 1.3e11),
        "granite_20b": (1.5e10, 2.5e10),
        "whisper_large_v3": (1.2e9, 2.5e9),
        "mamba2_130m": (0.9e8, 2e8),
        "deepseek_v2_236b": (2.0e11, 2.6e11),
        "grok_1_314b": (2.7e11, 3.6e11),
        "pixtral_12b": (0.9e10, 1.6e10),
        "hymba_1_5b": (1.0e9, 2.2e9),
    }
    lo, hi = expected_order[arch]
    assert lo <= n <= hi, f"{arch}: {n:.3e} params out of expected range"


def test_moe_active_params_below_total():
    cfg = get_config("deepseek_v2_236b")
    assert cfg.n_active_params() < 0.2 * cfg.n_params()


def test_sliding_window_masks_attention():
    """hymba window: token attends only within the window."""
    cfg = dataclasses.replace(get_smoke_config("hymba_1_5b"), dtype="float32",
                              n_layers=1, global_layers=())
    params = init_params(KEY, cfg)
    B, S = 1, 24
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    logits1, _ = T.forward_train(params, cfg, tokens)
    # perturb a token far outside the window of the last position
    w = cfg.window  # 16
    tokens2 = tokens.at[0, 1].set((tokens[0, 1] + 1) % cfg.vocab)
    logits2, _ = T.forward_train(params, cfg, tokens2)
    # ssm path still carries state; compare ATTENTION-ONLY by checking the
    # perturbation decays: positions within the window of pos 1 must change
    assert not np.allclose(np.asarray(logits1[0, 2]), np.asarray(logits2[0, 2]))


def test_mamba2_chunked_equals_small_chunk():
    """SSD chunked result is invariant to chunk size (algebraic identity)."""
    cfg = dataclasses.replace(get_smoke_config("mamba2_130m"), dtype="float32")
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 33), 0, cfg.vocab)  # non-multiple
    l1, _ = T.forward_train(params, cfg, tokens)
    cfg2 = dataclasses.replace(cfg, ssm_chunk=7)
    l2, _ = T.forward_train(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4, atol=2e-4)


def test_ring_buffer_long_decode_matches_linear():
    """Windowed decode with a ring buffer == linear buffer with window mask."""
    cfg = dataclasses.replace(get_smoke_config("hymba_1_5b"), dtype="float32",
                              global_layers=())
    params = init_params(KEY, cfg)
    B, S_ctx, n_gen = 1, 20, 6
    tokens = jax.random.randint(KEY, (B, S_ctx + n_gen), 0, cfg.vocab)

    # linear: prefill + decode with full buffers (window enforced by mask)
    _, caches_lin = T.prefill(params, cfg, tokens[:, :S_ctx])
    # ring: replay the whole prefix through ring-buffer decode
    caches_ring = T.init_decode_caches(cfg, B, S_ctx + n_gen + 1,
                                       long_context=True)
    for t in range(S_ctx):
        _, caches_ring = T.decode_step(params, cfg, caches_ring, tokens[:, t],
                                       jnp.asarray(t, jnp.int32))
    outs_l, outs_r = [], []
    for t in range(S_ctx, S_ctx + n_gen):
        lo_l, caches_lin = T.decode_step(params, cfg, caches_lin, tokens[:, t],
                                         jnp.asarray(t, jnp.int32))
        lo_r, caches_ring = T.decode_step(params, cfg, caches_ring, tokens[:, t],
                                          jnp.asarray(t, jnp.int32))
        outs_l.append(np.asarray(lo_l))
        outs_r.append(np.asarray(lo_r))
    np.testing.assert_allclose(np.stack(outs_l), np.stack(outs_r),
                               rtol=2e-4, atol=2e-4)
