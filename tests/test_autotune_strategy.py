"""Cost-model autotuner + sharded-strategy dispatch contracts.

Pins the PR 7 search/dispatch behavior: shard-count-explicit table keys
(a d-shard tune can never poison the 1-shard entry), strategy dispatch
(ring vs replicated) for the sharded all-pairs sweep driven from the
table, bit-identity of BOTH strategies against the single-device
triangle, and the two-stage search actually pruning with its analytic
cost model before measuring.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clock as bc
from repro.fleet import ClockRegistry
from repro.kernels import autotune, ops, pack
from repro.launch.mesh import make_fleet_mesh

RNG = np.random.default_rng(7)


def _packed_slab(n: int, m: int, hi: int = 9):
    cells = jnp.asarray(RNG.integers(0, hi, (n, m)), jnp.int32)
    u8, base, ok = pack.pack_rows(cells)
    assert bool(ok.all())
    return u8, base


def _plant(monkeypatch, tmp_path, table: dict):
    path = tmp_path / "table.json"
    path.write_text(json.dumps(table))
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(path))
    return path


# ---------------------------------------------------------------------------
# shard-explicit table keys
# ---------------------------------------------------------------------------

def test_key_for_is_shard_explicit():
    k1 = autotune.key_for("matrix", 512, 512, 512, True)
    k2 = autotune.key_for("matrix", 512, 512, 512, True, shards=2)
    assert k1.endswith("|s1") and k2.endswith("|s2") and k1 != k2
    # same bucketing as before on the shape axes
    assert autotune.key_for("matrix", 300, 300, 300, True) == \
        autotune.key_for("matrix", 512, 512, 512, True)


def test_sharded_tune_cannot_poison_one_shard_entry(monkeypatch, tmp_path):
    """Hand-planted conflict: a 2-shard entry and a 1-shard entry for
    the SAME bucketed shape must resolve independently — the historical
    bug resolved ring blocks at the per-shard sub-shape through the
    1-shard key, so tuning one could corrupt the other."""
    good = {"engine": "tri", "bi": 64, "bj": 64, "bm": 512, "us": 1.0}
    poison = {"strategy": "replicated", "bi": 8, "bj": 8, "bm": 128,
              "us": 1.0}
    _plant(monkeypatch, tmp_path, {
        autotune.key_for("matrix", 512, 512, 512, True): good,
        autotune.key_for("matrix_sharded", 512, 512, 512, True,
                         shards=2): poison,
    })
    assert autotune.lookup("matrix", 512, 512, 512, True) == good
    assert autotune.lookup("matrix_sharded", 512, 512, 512, True,
                           shards=2) == poison
    assert autotune.lookup("matrix_sharded", 512, 512, 512, True,
                           shards=4) is None
    # block resolution: the d-shard path reads ONLY the matrix_sharded
    # key at the GLOBAL shape; the 1-shard path keeps its own entry
    assert ops._matrix_blocks("full", 512, 512, 512, None, None, None,
                              True, shards=2) == (8, 8, 128)
    assert ops._matrix_blocks("tri", 512, 512, 512, None, None, None,
                              True) == (64, 64, 512)


def test_per_shard_subshape_lookup_not_aliased(monkeypatch, tmp_path):
    """A 1-shard entry for shape N/d must NOT leak into the d-shard ring
    for global shape N (whose per-shard blocks are N/d wide)."""
    _plant(monkeypatch, tmp_path, {
        # absurd blocks planted at the sub-shape a 2-shard ring of
        # N=512 used to resolve through
        autotune.key_for("matrix", 256, 256, 512, True):
            {"engine": "full", "bi": 8, "bj": 8, "bm": 128, "us": 1.0},
    })
    assert ops._matrix_blocks("full", 512, 512, 512, None, None, None,
                              True, shards=2) == (128, 128, 512)


# ---------------------------------------------------------------------------
# strategy dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy,label", [("replicated", "replicated_"),
                                            ("ring", "ring_full")])
def test_strategy_dispatch_from_table(host_devices, monkeypatch, tmp_path,
                                      strategy, label):
    """With no explicit strategy, the sharded front-door dispatches on
    the table's matrix_sharded entry and records its decision."""
    n, m = 32, 128
    _plant(monkeypatch, tmp_path, {
        autotune.key_for("matrix_sharded", n, n, m, True, shards=2):
            {"strategy": strategy, "bi": 128, "bj": 128, "bm": 512,
             "us": 1.0},
    })
    u8, base = _packed_slab(n, m)
    ref = jax.device_get(ops._compare_matrix_packed(u8, base))
    got = jax.device_get(ops._compare_matrix_packed_sharded(
        u8, base, mesh=make_fleet_mesh(2), axis="fleet"))
    assert ops.LAST_DISPATCH["engine"].startswith(label)
    assert ops.LAST_DISPATCH["strategy"] == strategy
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(got[k]), err_msg=k)


@pytest.mark.parametrize("shards", (1, 2, 3, 4, 8))
@pytest.mark.parametrize("strategy", ("ring", "replicated"))
def test_explicit_strategies_bit_identical(host_devices, shards, strategy):
    """Both strategies reproduce the single-device triangle bit-for-bit
    at every shard count, non-uniform §4 bases included."""
    n, m = 24, 160
    cells = jnp.asarray(
        RNG.integers(0, 9, (n, m)) + RNG.integers(0, 300, (n, 1)), jnp.int32)
    u8, base, ok = pack.pack_rows(cells)
    assert bool(ok.all())
    ref = jax.device_get(ops._compare_matrix_packed(u8, base))
    got = jax.device_get(ops._compare_matrix_packed_sharded(
        u8, base, mesh=make_fleet_mesh(shards), axis="fleet",
        strategy=strategy))
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(got[k]), err_msg=k)


def test_unknown_strategy_raises(host_devices):
    u8, base = _packed_slab(16, 128)
    with pytest.raises(ValueError, match="strategy"):
        ops._compare_matrix_packed_sharded(
            u8, base, mesh=make_fleet_mesh(2), axis="fleet",
            strategy="gossip")


def test_registry_replicated_strategy_with_dead_and_promoted(
        host_devices, monkeypatch, tmp_path):
    """End-to-end: a table-planted replicated strategy drives the
    registry's sharded all_pairs — dead slots and a promoted row stay
    bit-identical to the unsharded registry."""
    cap, m, k = 16, 128, 3
    _plant(monkeypatch, tmp_path, {
        autotune.key_for("matrix_sharded", cap, cap, m, True, shards=2):
            {"strategy": "replicated", "bi": 128, "bj": 128, "bm": 512,
             "us": 1.0},
    })
    rows = RNG.integers(0, 9, (cap, m))
    rows[5, ::5] = 2000                        # promoted (span > u8)
    peers = {f"p{i}": bc.BloomClock(jnp.asarray(rows[i], jnp.int32),
                                    jnp.zeros((), jnp.int32), k)
             for i in range(cap)}
    ref_reg = ClockRegistry(capacity=cap, m=m, k=k)
    ref_reg.admit_many(peers)
    ref_reg.evict_many(["p2", "p9"])
    reg = ClockRegistry(capacity=cap, m=m, k=k, mesh=make_fleet_mesh(2))
    reg.admit_many(peers)
    reg.evict_many(["p2", "p9"])
    ref = jax.device_get(ref_reg.all_pairs())
    got = jax.device_get(reg.all_pairs())
    assert "replicated" in got.engine
    for key in ("a_le_b", "b_le_a", "concurrent"):
        np.testing.assert_array_equal(np.asarray(got[key], bool),
                                      np.asarray(ref[key], bool),
                                      err_msg=key)
    assert (np.asarray(got["fp"]) == np.asarray(ref["fp"])).all()


# ---------------------------------------------------------------------------
# cost model + pruned search
# ---------------------------------------------------------------------------

def test_predict_cost_vmem_bust_is_infinite():
    assert autotune.predict_cost("tri", 4096, 4096, 4096,
                                 1024, 1024, 4096, False) == math.inf
    assert autotune.predict_cost("tri", 256, 256, 512,
                                 128, 128, 512, True) < math.inf


def test_predict_cost_ranks_step_overhead_on_interpret():
    """Interpret mode is dominated by per-grid-step overhead, so fewer,
    bigger blocks must rank strictly cheaper."""
    few = autotune.predict_cost("tri", 1024, 1024, 1024, 256, 256, 1024, True)
    many = autotune.predict_cost("tri", 1024, 1024, 1024, 8, 8, 128, True)
    assert few < many


def test_predict_sharded_cost_backend_dependent(monkeypatch):
    """Serialized-host meshes (CI) predict replicated; physically
    parallel meshes predict the ring."""
    ring_ci = autotune.predict_sharded_cost("ring", 1024, 1024, 4, True)
    repl_ci = autotune.predict_sharded_cost("replicated", 1024, 1024, 4, True)
    assert repl_ci < ring_ci
    monkeypatch.setattr(autotune, "_host_serialized", lambda interpret: False)
    ring_hw = autotune.predict_sharded_cost("ring", 1024, 1024, 4, False)
    repl_hw = autotune.predict_sharded_cost("replicated", 1024, 1024, 4,
                                            False)
    assert ring_hw < repl_hw


def test_prune_measures_at_most_half(monkeypatch, tmp_path):
    """The measured stage sees at most half the knob grid (and VMEM
    busts never survive), with the counters recording the deltas."""
    _plant(monkeypatch, tmp_path, {})       # isolate the shipped table
    before = dict(autotune.SEARCH_STATS)
    exp = {}
    best = autotune.autotune_matrix(16, 128, span=10, interpret=True,
                                    explain=exp)
    assert best["engine"] in ("tri", "i32", "mxu") and best["us"] > 0
    assert exp["survivors"] <= max(1, exp["grid"] // 2)
    assert len(exp["measured"]) <= exp["survivors"]
    d_cand = autotune.SEARCH_STATS["candidates"] - before["candidates"]
    d_pruned = autotune.SEARCH_STATS["pruned"] - before["pruned"]
    assert d_cand == exp["grid"]
    assert d_pruned == exp["grid"] - exp["survivors"]
    # predicted ranking is exposed for --explain, best-first
    preds = [p["pred_us"] for p in exp["predicted"]]
    assert preds == sorted(preds)


def test_vmem_bytes_matches_template_estimate():
    """The search prices candidates with the SAME model the kernel
    generator refuses over-budget specs with."""
    from repro.kernels.template import CompareSpec, vmem_estimate
    assert autotune.vmem_bytes("tri", 8, 8, 128) == vmem_estimate(
        CompareSpec(topology="tri", pack="u8", bi=8, bj=8, bm=128,
                    pipeline_depth=1))
    assert autotune.vmem_bytes("mxu", 128, 128, 128, 64) == vmem_estimate(
        CompareSpec(topology="mxu", pack="u8", bi=128, bj=128, bm=128,
                    with_base=True, pipeline_depth=1, n_thresholds=64))


def test_autotune_sweep_emits_observer_spans(monkeypatch, tmp_path):
    """autotune_shapes records one autotune.sweep span per (op, shape)
    with search counters, through the standard Observer plumbing."""
    from repro.obs import MetricsRecorder, Observer, Tracer
    _plant(monkeypatch, tmp_path, {})
    obs = Observer(trace=Tracer(), metrics=MetricsRecorder())
    table = autotune.autotune_shapes([(16, 128)], observer=obs,
                                     interpret=True)
    assert len(table) == 3                   # matrix + one_vs_many + hybrid
    spans = [e for e in obs.trace.events() if e["name"] == "autotune.sweep"]
    assert {e["attrs"]["op"] for e in spans} == {"matrix", "one_vs_many",
                                                "hybrid"}
    for e in spans:
        assert "winner" in e["attrs"] and e["attrs"]["measured"] >= 1
