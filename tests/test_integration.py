"""Integration tests: the paper's technique load-bearing in the framework.

- checkpoint lineage gating (restore from ancestor OK, fork refused)
- async local-SGD with clock-guarded merges (forked pod quarantined,
  straggler skipped, training still converges)
- serving session migration gated by clock comparison
- elastic reshard restore
- end-to-end train loss decreases
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import clock as bc
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.params import init_params
from repro.optim.adamw import OptConfig
from repro.runtime.async_trainer import (AsyncConfig, AsyncCoordinator,
                                         run_pod_round)
from repro.runtime.clock_runtime import ClockConfig, ClockRuntime, LineageStatus
from repro.runtime.training import (cross_entropy, init_train_state,
                                    make_train_step)
from repro.serving.engine import ServeConfig, ServingEngine

KEY = jax.random.PRNGKey(0)
CFG = get_smoke_config("qwen1_5_0_5b")


def _mk_batch(data, step):
    b = data.batch(step)
    hi, lo = data.event_id(step)
    b["ev_hi"] = jnp.uint32(hi)
    b["ev_lo"] = jnp.uint32(lo)
    return b


class TestTrainLoop:
    def test_loss_decreases(self):
        opt = OptConfig(lr=3e-3, total_steps=40)
        ck = ClockConfig(m=128)
        state = init_train_state(KEY, CFG, opt, ck)
        step_fn = jax.jit(make_train_step(CFG, opt, ck))
        data = SyntheticLM(DataConfig(vocab=CFG.vocab, seq_len=64, global_batch=8))
        losses = []
        for s in range(40):
            state, m = step_fn(state, _mk_batch(data, s))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5
        # clock ticked once per step
        assert float(jnp.sum(state.clock_cells)) == 40 * ck.k

    def test_microbatched_grads_match(self):
        opt = OptConfig(lr=1e-3, total_steps=10)
        ck = ClockConfig(m=64)
        cfg32 = dataclasses.replace(CFG, dtype="float32")
        state = init_train_state(KEY, cfg32, opt, ck)
        data = SyntheticLM(DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=8))
        b = _mk_batch(data, 0)
        s1, m1 = jax.jit(make_train_step(cfg32, opt, ck, num_microbatches=1))(state, b)
        s4, m4 = jax.jit(make_train_step(cfg32, opt, ck, num_microbatches=4))(state, b)
        np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                                   rtol=1e-4)
        for k in list(state.params)[:4]:
            np.testing.assert_allclose(np.asarray(s1.params[k]),
                                       np.asarray(s4.params[k]),
                                       rtol=2e-4, atol=2e-5)


class TestCheckpointLineage:
    def test_save_restore_roundtrip(self, tmp_path):
        opt = OptConfig(total_steps=10)
        ck = ClockConfig(m=64)
        state = init_train_state(KEY, CFG, opt, ck)
        rt = ClockRuntime(ck, run_id="t0")
        rt.tick_step(0)
        mgr = CheckpointManager(str(tmp_path), run_id="t0")
        mgr.save(1, state, rt.snapshot(), block=True)
        restored, manifest = mgr.restore(target_structure=state)
        assert manifest["step"] == 1
        for k in list(state.params)[:3]:
            np.testing.assert_array_equal(np.asarray(state.params[k]),
                                          np.asarray(restored.params[k]))

    def test_ancestor_restore_admitted_fork_refused(self, tmp_path):
        ck = ClockConfig(m=256, fp_threshold=0.5)
        live = ClockRuntime(ck, run_id="r")
        ckpt = ClockRuntime(ck, run_id="r")
        # shared prefix
        for s in range(5):
            live.tick_step(s)
            ckpt.tick_step(s)
        # live advances beyond the checkpoint -> checkpoint is an ancestor
        live.tick_step(5)
        ok, status, fp = live.admit_restore(ckpt.clock)
        assert status == LineageStatus.ANCESTOR and ok
        # forked checkpoint: ticked an event live never saw
        forked = ClockRuntime(ck, run_id="r")
        for s in range(5):
            forked.tick_step(s)
        forked.tick("rogue-event")
        live.tick_step(6)
        ok2, status2, _ = live.admit_restore(forked.clock)
        assert status2 == LineageStatus.FORKED and not ok2

    def test_elastic_reshard_restore(self, tmp_path):
        """Restore under a different mesh: leaves land with new shardings."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        opt = OptConfig(total_steps=10)
        ck = ClockConfig(m=64)
        state = init_train_state(KEY, CFG, opt, ck)
        mgr = CheckpointManager(str(tmp_path), run_id="t0")
        rt = ClockRuntime(ck)
        mgr.save(1, state, rt.snapshot(), block=True)
        mesh = jax.make_mesh((1,), ("data",))
        shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), state)
        restored, _ = mgr.restore(target_structure=state, shardings=shardings)
        leaf = restored.params["layers/attn/wq"]
        assert leaf.sharding.mesh.shape == {"data": 1}


class TestAsyncClockGuard:
    def _setup(self):
        cfg32 = dataclasses.replace(CFG, dtype="float32")
        opt = OptConfig(lr=2e-3, total_steps=200)
        params = init_params(KEY, cfg32)
        a_cfg = AsyncConfig(n_pods=3, local_steps=3, outer_lr=0.5)
        c_cfg = ClockConfig(m=256, fp_threshold=1.0 - 1e-6, straggler_gap=1e9)
        coord = AsyncCoordinator(params, a_cfg, c_cfg)
        pods = coord.add_pods(list(range(a_cfg.n_pods)), c_cfg)
        data = SyntheticLM(DataConfig(vocab=cfg32.vocab, seq_len=32,
                                      global_batch=4))

        def loss_fn(p, batch):
            from repro.models import transformer as T
            logits, _ = T.forward_train(p, cfg32, batch["tokens"])
            return cross_entropy(logits, batch["labels"], cfg32.vocab)

        @jax.jit
        def sgd_step(p, batch):
            l, g = jax.value_and_grad(loss_fn)(p, batch)
            return jax.tree.map(lambda w, gr: w - 2e-3 * gr, p, g), l

        def data_fn(pod_id, step):
            return data.batch(step * 10 + pod_id)

        return coord, pods, a_cfg, sgd_step, data_fn

    def test_healthy_pods_all_merge(self):
        coord, pods, a_cfg, sgd_step, data_fn = self._setup()
        deltas = {}
        for pod in pods:
            d, _ = run_pod_round(pod, sgd_step, data_fn, a_cfg, 0)
            deltas[pod.pod_id] = d
        decisions = coord.outer_step(pods, deltas)
        assert all(ok for ok, _, _ in decisions.values())

    def test_elastic_pod_churn_never_exhausts_registry(self):
        """Retired pod ids free their registry slots: churning through
        many more distinct pods than the slab holds must keep working."""
        coord, pods, a_cfg, sgd_step, data_fn = self._setup()
        cap = coord.registry.capacity
        c_cfg = coord.clock.cfg
        next_id = len(pods)
        for rnd in range(3):
            deltas = {}
            for pod in pods:
                d, _ = run_pod_round(pod, sgd_step, data_fn, a_cfg, rnd)
                deltas[pod.pod_id] = d
            decisions = coord.outer_step(pods, deltas)
            assert all(ok for ok, _, _ in decisions.values()), decisions
            # full fleet replacement each round: cap+ distinct ids total
            pods = coord.add_pods(
                list(range(next_id, next_id + cap // 2)), c_cfg)
            next_id += cap // 2
        assert len(coord.registry) <= cap

    def test_forked_pod_quarantined(self):
        """A pod restored from a pre-commit snapshot that then does local
        work is CONCURRENT with the advanced coordinator -> quarantined.
        (The fork is only detectable once the coordinator has committed a
        round the pod missed — correct causality semantics.)"""
        coord, pods, a_cfg, sgd_step, data_fn = self._setup()
        deltas = {}
        stale_snapshot = None
        for pod in pods:
            d, _ = run_pod_round(pod, sgd_step, data_fn, a_cfg, 0)
            deltas[pod.pod_id] = d
            if pod.pod_id == 2:
                stale_snapshot = pod.clock.clock  # pre-commit state
        decisions = coord.outer_step(pods, deltas)  # commit round 0
        assert all(ok for ok, _, _ in decisions.values())
        # pod 2 crashes, restores the stale snapshot, works independently
        pods[2].clock.clock = stale_snapshot
        deltas2 = {}
        for pod in pods:
            d, _ = run_pod_round(pod, sgd_step, data_fn, a_cfg, 50)
            deltas2[pod.pod_id] = d
        decisions2 = coord.outer_step(pods, deltas2)
        assert decisions2[0][0] and decisions2[1][0]
        assert not decisions2[2][0]
        assert decisions2[2][1] == LineageStatus.FORKED

    def test_straggler_skipped_then_catches_up(self):
        coord, pods, a_cfg, sgd_step, data_fn = self._setup()
        # tighten straggler gap: one idle round (12 missed ticks) trips it
        coord_cfg = dataclasses.replace(coord.clock.cfg, straggler_gap=4.0)
        coord.clock.cfg = coord_cfg
        deltas = {}
        for pod in pods[:2]:  # pod 2 does no work this round
            d, _ = run_pod_round(pod, sgd_step, data_fn, a_cfg, 0)
            deltas[pod.pod_id] = d
        deltas[2] = jax.tree.map(jnp.zeros_like, deltas[0])
        decisions = coord.outer_step(pods, deltas)
        assert not decisions[2][0] and decisions[2][1] == "straggler"
        # pod 2 resyncs to the published UNION clock -> its sum equals the
        # fleet's; after one working round it is re-admitted
        pods[2].clock.clock = bc.merge(pods[2].clock.clock, coord.clock.clock)
        d, _ = run_pod_round(pods[2], sgd_step, data_fn, a_cfg, 100)
        for pod in pods[:2]:
            deltas[pod.pod_id], _ = run_pod_round(pod, sgd_step, data_fn,
                                                  a_cfg, 100)
        deltas[2] = d
        decisions2 = coord.outer_step(pods, deltas)
        assert decisions2[2][0], decisions2


class TestServing:
    def test_generate_and_migration_guard(self):
        cfg32 = dataclasses.replace(CFG, dtype="float32")
        params = init_params(KEY, cfg32)
        c_cfg = ClockConfig(m=256, fp_threshold=1.0 - 1e-6)
        eng_a = ServingEngine(params, cfg32, ServeConfig(max_seq=64), c_cfg,
                              replica_id="A")
        prompts = jax.random.randint(KEY, (2, 8), 0, cfg32.vocab)
        sess = eng_a.admit(prompts)
        toks = eng_a.generate(sess, 4)
        assert toks.shape == (2, 4)
        # greedy decode must match teacher-forced continuation argmax
        # replica B that shares A's history can adopt the session
        eng_b = ServingEngine(params, cfg32, ServeConfig(max_seq=64), c_cfg,
                              replica_id="B")
        eng_b.clock.clock = bc.merge(eng_b.clock.clock, eng_a.clock.clock)
        ok, status, _ = eng_b.can_adopt(sess)
        assert ok, status
        # a fresh replica that never saw the session's history must refuse
        eng_c = ServingEngine(params, cfg32, ServeConfig(max_seq=64), c_cfg,
                              replica_id="C")
        eng_c.clock.tick("own-history")
        ok2, status2, _ = eng_c.can_adopt(sess)
        assert not ok2 and status2 == LineageStatus.FORKED
        # bulk migration agrees with the scalar guard in one kernel call
        mask = eng_b.adopt_many([sess])
        assert list(mask) == [True]
        assert sess["sid"] in eng_b.sessions

    def test_session_registry_bounded_and_releasable(self):
        """The session-clock registry must never crash a long-running
        engine: oldest sessions evict FIFO at capacity, release() frees
        slots, adopt() writes the minted sid back."""
        cfg32 = dataclasses.replace(CFG, dtype="float32")
        params = init_params(KEY, cfg32)
        c_cfg = ClockConfig(m=128, fp_threshold=1.0 - 1e-6)
        eng = ServingEngine(params, cfg32, ServeConfig(max_seq=64), c_cfg,
                            replica_id="A")
        cap = eng.sessions.capacity
        prompts = jax.random.randint(KEY, (2, 8), 0, cfg32.vocab)
        last = None
        for _ in range(cap + 3):
            last = eng.admit(prompts)
        assert len(eng.sessions) == cap          # FIFO-bounded, no raise
        assert last["sid"] in eng.sessions       # newest survives
        eng.release(last)
        assert last["sid"] not in eng.sessions
        assert len(eng.sessions) == cap - 1
        migrated = {"clock": last["clock"]}
        assert eng.adopt(migrated)
        assert migrated["sid"] in eng.sessions   # sid written back


class TestSimulatorVsPaper:
    def test_fig6_style_trace(self):
        """5-node hand trace mirroring paper Fig. 6 semantics."""
        m, k = 8, 2
        clocks = {n: bc.zeros(m, k) for n in "ABCDE"}

        def ev(node, i):
            clocks[node] = bc.tick(clocks[node], jnp.uint32(0), jnp.uint32(i))
            return clocks[node]

        def recv(dst, snapshot):
            clocks[dst] = bc.merge(clocks[dst], snapshot)

        t1 = ev("A", 1)
        for n in "BDE":       # C missed A's broadcast
            recv(n, t1)
        t2 = ev("B", 2)
        for n in "AE":        # C, D missed
            recv(n, t2)
        # A,B,E identical so far; D only saw t1; C nothing
        assert bool(bc.ordering(clocks["A"], clocks["E"]).equal)
        assert bool(bc.ordering(clocks["D"], clocks["A"]).a_le_b)
        t3 = ev("D", 3)       # D advances independently of t2
        o = bc.ordering(clocks["D"], clocks["E"])
        # D(t1+t3) vs E(t1+t2): concurrent — exactly the paper's first
        # incomparable pair
        assert bool(o.concurrent)
        recv("E", t3)         # E merges -> dominates everyone now
        for n in "ABCD":
            assert bool(bc.ordering(clocks[n], clocks["E"]).a_le_b)

    def test_eq3_against_monte_carlo_band(self):
        """Eq. 3 is a (conservative) approximation: MC-true overlap must not
        EXCEED the Eq. 3 prediction for these regimes (documented in
        EXPERIMENTS.md)."""
        from repro.core.sim import monte_carlo_overlap

        for m, sa, sb in [(6, 7, 10), (64, 20, 60), (128, 50, 100)]:
            pred = float(bc.fp_rate(sa, sb, m))
            mc = monte_carlo_overlap(m, sa, sb, trials=30_000, seed=1)
            assert mc <= pred + 0.02, (m, sa, sb, mc, pred)
