"""Streaming admission pipeline: gating, digest cache, audit replay —
plus the near-INT32_MAX ``adopt_many`` merge regression (a raw
``jnp.maximum`` merge zeroes a wrapped local clock against sane peers;
the wrap-safe ``core.clock.merge`` fold must not).
"""
import dataclasses
import types

import jax.numpy as jnp
import numpy as np
import pytest

from repro.causal.policy import CausalPolicy
from repro.core import clock as bc
from repro.core import wire
from repro.fleet.registry import INT32_MAX
from repro.obs import AuditTrail, Observer
from repro.serve.pipeline import AdmissionPipeline, PipelineConfig
from repro.serve.tiers import TierConfig, TieredRegistry

M, K = 32, 3

CFG = TierConfig(hot_capacity=16, warm_capacity=32, promote_after=2,
                 demote_batch=4, spill_batch=8, cold_batch=8)


def _tick_n(c, n, salt=0):
    for i in range(n):
        c = bc.tick(c, jnp.uint32(salt), jnp.uint32(i + 1))
    return c


def _mk(observer=None, threshold=1.0, batch=8):
    pol = CausalPolicy(fp_threshold=threshold, observer=observer)
    tiers = TieredRegistry(CFG, m=M, k=K, policy=pol)
    local = {"clock": _tick_n(bc.zeros(M, K), 12)}
    pipe = AdmissionPipeline(
        tiers, lambda: local["clock"],
        PipelineConfig(batch_size=batch, max_wait_s=0.002))
    return tiers, pipe, local


def test_admit_gate_and_query_roundtrip():
    tiers, pipe, local = _mk()
    try:
        past = _tick_n(bc.zeros(M, K), 4)           # prefix of local
        # same private event 40x: its cells exceed anything local ever
        # counted, so no Bloom collision can make it look like a prefix
        forked = bc.zeros(M, K)
        for _ in range(40):
            forked = bc.tick(forked, jnp.uint32(999), jnp.uint32(7))
        t_ok = pipe.submit("anc", clock=past)
        t_no = pipe.submit("fork", clock=forked)
        pipe.drain(timeout=60)
        v_ok, v_no = t_ok.result(1), t_no.result(1)
        assert v_ok.admitted and v_ok.verdict == "ancestor"
        assert v_ok.engine and v_ok.engine != "digest_cache"
        assert not v_no.admitted and v_no.verdict == "forked"
        # admitted sessions are queryable after drain(); rejects are not
        assert "anc" in tiers and "fork" not in tiers
        q = pipe.submit("anc", kind="query")
        qq = pipe.submit("ghost", kind="query")
        pipe.drain(timeout=60)
        assert q.result(1).verdict == "ancestor"
        assert qq.result(1).verdict == "unknown"
        assert pipe.n_admitted == 1 and pipe.n_rejected == 1
        assert pipe.n_queries == 2
        assert pipe.latency_quantiles()["p50"] > 0
    finally:
        pipe.close()
        tiers.close()


def test_digest_cache_hits_and_invalidation():
    tiers, pipe, local = _mk()
    try:
        frame = wire.encode_clock(bc.to_wire(_tick_n(bc.zeros(M, K), 3)))
        pipe.submit("a0", frame=frame)
        pipe.drain(timeout=60)
        t = [pipe.submit(f"a{i}", frame=frame) for i in range(1, 4)]
        pipe.drain(timeout=60)
        assert all(x.result(1).cached for x in t)
        assert all(x.result(1).engine == "digest_cache" for x in t)
        assert all(x.result(1).admitted for x in t)
        assert pipe.cache_hits == 3
        # a local tick invalidates every entry: same frame misses again
        local["clock"] = bc.tick(local["clock"], jnp.uint32(1),
                                 jnp.uint32(77))
        t2 = pipe.submit("a9", frame=frame)
        pipe.drain(timeout=60)
        assert not t2.result(1).cached
        assert pipe.cache_hits == 3 and pipe.cache_misses >= 2
    finally:
        pipe.close()
        tiers.close()


def test_pipeline_audit_replays_bit_identical():
    trail = AuditTrail(store_frames=True)
    tiers, pipe, local = _mk(observer=Observer(audit=trail))
    try:
        rng = np.random.default_rng(5)
        for i in range(20):
            c = _tick_n(bc.zeros(M, K), int(rng.integers(1, 10)),
                        salt=int(rng.integers(0, 3)))
            pipe.submit(f"s{i}", clock=c)
        pipe.drain(timeout=120)
        for i in range(6):
            pipe.submit(f"s{i}", kind="query")
        pipe.drain(timeout=120)
        n_acted = sum(1 for r in trail.verdicts())
        assert n_acted >= 20
        rep = trail.replay_frames(
            policy=dataclasses.replace(tiers.policy, observer=None))
        assert rep.checked > 0 and not rep.mismatches, rep.mismatches
        assert rep.matched == rep.checked
    finally:
        pipe.close()
        tiers.close()


def test_queue_backpressure_counts_every_request():
    tiers, pipe, local = _mk(batch=4)
    try:
        past = _tick_n(bc.zeros(M, K), 2)
        frame = wire.encode_clock(bc.to_wire(past))
        tickets = [pipe.submit(f"b{i}", frame=frame) for i in range(40)]
        pipe.drain(timeout=120)
        assert all(t.result(1).admitted for t in tickets)
        assert pipe.n_admitted == 40
        assert pipe.stats()["batches"] >= 1
    finally:
        pipe.close()
        tiers.close()


# ---------------------------------------------------------------------------
# satellite: adopt_many near-INT32_MAX merge regression
# ---------------------------------------------------------------------------
def test_adopt_many_merge_survives_int32_wrap():
    """Local replica clock with logical cells past INT32_MAX (negative
    in the i32 representation).  A sane ancestor peer is accepted; the
    bulk merge must leave local's mod-2^32 position intact.  The old
    ``jnp.maximum(peer, local)`` merge collapses every wrapped cell to
    the peer's small value — billions of events lost."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_smoke_config
    from repro.models.params import init_params
    from repro.runtime.clock_runtime import ClockConfig, ClockRuntime
    from repro.serving.engine import ServeConfig, ServingEngine

    cfg32 = dataclasses.replace(get_smoke_config("qwen1_5_0_5b"),
                                dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg32)
    c_cfg = ClockConfig(m=M, fp_threshold=1.0)
    eng = ServingEngine(params, cfg32, ServeConfig(max_seq=32), c_cfg,
                        replica_id="rim")
    wrapped = np.uint64(INT32_MAX) + np.uint64(21)     # 2**31 + 20
    local_u32 = np.full(M, wrapped, np.uint64)
    eng.clock.clock = bc.BloomClock(
        cells=jnp.asarray(local_u32.astype(np.uint32).view(np.int32)),
        base=jnp.zeros((), jnp.int32), k=c_cfg.k)
    # peer at 100 events per cell: (local - peer) mod 2^32 < 2^31, so
    # the wraparound-safe compare says peer ≼ local -> adoptable
    peer = bc.BloomClock(cells=jnp.full((M,), 100, jnp.int32),
                         base=jnp.zeros((), jnp.int32), k=c_cfg.k)
    sess = {"clock": types.SimpleNamespace(clock=peer)}
    mask = eng.adopt_many([sess])
    assert list(mask) == [True]
    after = (np.asarray(eng.clock.clock.logical_cells())
             .astype(np.int64) & 0xFFFFFFFF)
    np.testing.assert_array_equal(
        after, local_u32.astype(np.int64),
        err_msg="wrapped local clock corrupted by adopt_many merge")


def test_adopt_routes_through_batched_classify_audit():
    """Single-session adopt() is the batch-of-one path: its audit
    record carries the real dispatch engine, not a fixed label."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_smoke_config
    from repro.models.params import init_params
    from repro.runtime.clock_runtime import ClockConfig, ClockRuntime
    from repro.serving.engine import ServeConfig, ServingEngine

    trail = AuditTrail(store_frames=True)
    cfg32 = dataclasses.replace(get_smoke_config("qwen1_5_0_5b"),
                                dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg32)
    c_cfg = ClockConfig(m=M, fp_threshold=1.0,
                        policy=CausalPolicy(fp_threshold=1.0,
                                            observer=Observer(audit=trail)))
    eng = ServingEngine(params, cfg32, ServeConfig(max_seq=32), c_cfg,
                        replica_id="A")
    eng.clock.tick("warm", 1)
    peer = ClockRuntime(c_cfg, run_id="serve")
    peer.clock = bc.merge(peer.clock, eng.clock.clock)
    assert eng.adopt({"clock": peer})
    recs = [r for r in trail.verdicts() if r.transport == "serving"]
    assert recs and recs[-1].action == "adopt"
    assert recs[-1].engine          # real engine label, never empty
    rep = trail.replay_frames(
        policy=dataclasses.replace(eng.clock.policy, observer=None))
    assert rep.matched == rep.checked and not rep.mismatches
