"""Unit tests for the Bloom clock core (paper §3/§4 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clock as bc
from repro.core import vector_clock as vc
from repro.core.hashing import bloom_indices, stable_event_id


def _ev(i):
    return jnp.uint32(0), jnp.uint32(i)


class TestTick:
    def test_tick_adds_k_increments(self):
        c = bc.zeros(64, k=3)
        c = bc.tick(c, *_ev(7))
        assert float(bc.clock_sum(c)) == 3.0

    def test_tick_batch_of_events(self):
        c = bc.zeros(64, k=4)
        hi = jnp.zeros((5,), jnp.uint32)
        lo = jnp.arange(5, dtype=jnp.uint32)
        c = bc.tick(c, hi, lo)
        assert float(bc.clock_sum(c)) == 20.0

    def test_deterministic(self):
        a = bc.tick(bc.zeros(128, k=4), *_ev(42))
        b = bc.tick(bc.zeros(128, k=4), *_ev(42))
        assert bool(jnp.all(a.cells == b.cells))

    def test_different_events_differ(self):
        a = bc.tick(bc.zeros(1024, k=4), *_ev(1))
        b = bc.tick(bc.zeros(1024, k=4), *_ev(2))
        assert not bool(jnp.all(a.cells == b.cells))


class TestCompare:
    def test_self_after_tick_is_ordered(self):
        c0 = bc.tick(bc.zeros(64, k=3), *_ev(1))
        c1 = bc.tick(c0, *_ev(2))
        o = bc.ordering(c0, c1)
        assert bool(o.a_le_b) and not bool(o.b_le_a) and not bool(o.concurrent)

    def test_merge_dominates_both(self):
        a = bc.tick(bc.zeros(64, k=3), *_ev(1))
        b = bc.tick(bc.zeros(64, k=3), *_ev(2))
        m = bc.merge(a, b)
        assert bool(bc.ordering(a, m).a_le_b)
        assert bool(bc.ordering(b, m).a_le_b)

    def test_equal(self):
        a = bc.tick(bc.zeros(64, k=3), *_ev(9))
        o = bc.ordering(a, a)
        assert bool(o.equal) and bool(o.a_le_b) and bool(o.b_le_a)


class TestEq3:
    def test_paper_worked_example(self):
        """Paper §3: m=6, ΣB=10, ΣA=7 -> (1-(1-1/6)^10)^7 = 0.29."""
        fp = float(bc.fp_rate(7, 10, 6))
        assert fp == pytest.approx(0.2914, abs=1e-3)

    def test_monotone_in_gap(self):
        """Larger ΣB - ΣA gap -> larger fp (paper Eq. 2 intuition)."""
        fps = [float(bc.fp_rate(10, 10 + g, 64)) for g in (0, 10, 100, 1000)]
        assert fps == sorted(fps)

    def test_stable_at_huge_sums(self):
        fp = float(bc.fp_rate(1e8, 1e9, 1024))
        assert 0.0 <= fp <= 1.0 and np.isfinite(fp)

    def test_zero_sums(self):
        assert float(bc.fp_rate(0, 0, 64)) == pytest.approx(1.0)
        # empty A trivially "inside" any B -> fp = 1 (claim carries no info)
        assert float(bc.fp_rate(0, 100, 64)) == pytest.approx(1.0)


class TestCompression:
    def test_paper_section4_example(self):
        """[4,3,3,5,7,4,3,3,5] -> (3)[1,0,0,2,4,1,0,0,2]."""
        cells = jnp.asarray([4, 3, 3, 5, 7, 4, 3, 3, 5], jnp.int32)
        c = bc.BloomClock(cells=cells, base=jnp.int32(0), k=3)
        z = bc.compress(c)
        assert int(z.base) == 3
        assert z.cells.tolist() == [1, 0, 0, 2, 4, 1, 0, 0, 2]

    def test_compress_preserves_semantics(self):
        c = bc.zeros(16, k=4)
        for i in range(20):
            c = bc.tick(c, *_ev(i))
        z = bc.compress(c)
        assert bool(jnp.all(z.logical_cells() == c.logical_cells()))
        assert float(bc.clock_sum(z)) == float(bc.clock_sum(c))
        d = bc.decompress(z)
        assert bool(jnp.all(d.cells == c.logical_cells()))

    def test_merge_after_compress(self):
        a = bc.zeros(16, k=4)
        b = bc.zeros(16, k=4)
        for i in range(10):
            a = bc.tick(a, *_ev(i))
            b = bc.tick(b, *_ev(i + 100))
        m1 = bc.merge(a, b)
        m2 = bc.merge(bc.compress(a), bc.compress(b))
        assert bool(jnp.all(m1.logical_cells() == m2.logical_cells()))


class TestVectorClockBaseline:
    def test_ordering(self):
        a = vc.zeros(4)
        a = vc.tick(a, 0)
        b = vc.merge(a, vc.tick(vc.zeros(4), 1))
        b = vc.tick(b, 1)
        o = vc.compare(a, b)
        assert bool(o.a_le_b) and not bool(o.b_le_a)

    def test_concurrent(self):
        a = vc.tick(vc.zeros(4), 0)
        b = vc.tick(vc.zeros(4), 1)
        assert bool(vc.compare(a, b).concurrent)

    def test_space_scaling(self):
        """§2/§4: vector O(N) vs bloom O(m) wire size."""
        assert vc.wire_bytes(10_000) > 16 * vc.wire_bytes(100)
        m = 1024  # bloom stays constant
        assert m * 4 == 4096


class TestHashing:
    def test_indices_in_range(self):
        idx = bloom_indices(jnp.uint32(123), jnp.uint32(456), 8, 100)
        assert idx.shape == (8,)
        assert bool(jnp.all(idx < 100))

    def test_uniformity(self):
        n = 20_000
        hi = jnp.zeros((n,), jnp.uint32)
        lo = jnp.arange(n, dtype=jnp.uint32)
        idx = np.asarray(bloom_indices(hi, lo, 4, 64)).reshape(-1)
        counts = np.bincount(idx, minlength=64)
        expect = n * 4 / 64
        # chi-square-ish sanity: all bins within 10% of uniform
        assert np.all(np.abs(counts - expect) < 0.1 * expect)

    def test_stable_event_id_deterministic(self):
        assert stable_event_id("a", 1) == stable_event_id("a", 1)
        assert stable_event_id("a", 1) != stable_event_id("a", 2)
        assert stable_event_id(b"xy") != stable_event_id("yx")


class TestHistory:
    def test_closest_predecessor_refines_fp(self):
        """§3: comparing against the closest dominating timestamp gives a
        smaller fp than against the newest."""
        from repro.core import history as hist

        c = bc.zeros(64, k=3)
        h = hist.init(window=16, m=64, k=3)
        snapshots = []
        for i in range(12):
            c = bc.tick(c, *_ev(i))
            h = hist.push(h, c)
            snapshots.append(c)
        other = snapshots[2]  # an old timestamp another node holds
        fp_newest = float(bc.ordering(other, c).fp_a_before_b)
        fp_best, idx = hist.best_predecessor_fp(h, other)
        assert float(fp_best) <= fp_newest
        assert float(fp_best) < 1.0
