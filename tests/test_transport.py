"""Transport fabric validation.

- wire robustness: truncated / corrupted / unknown-version / oversized
  frames are rejected with clear errors, round-trips are lossless for
  u8-packed AND int32-promoted rows (hypothesis property), including
  rows fetched across registry shard boundaries;
- loopback bit-identity: ``gossip_round`` (now a loopback session) is
  compared mask-for-mask, fp-bit-for-fp-bit, and cell-for-cell against
  a verbatim copy of the PRE-refactor round on the same fixtures;
- socket sessions: identical decisions to loopback from the same peer
  data, delta skipping after convergence, corrupted-push rejection, and
  the audited gossip sim (zero false negatives) over real TCP servers;
- mesh sessions: the ppermute digest ring agrees with the slab and the
  session matches the loopback decisions on a sharded registry;
- ClockRuntime.gossip: the transport argument end-to-end.
"""
import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro import causal
from repro.causal import CausalPolicy
from repro.core import clock as bc
from repro.core import wire
from repro.core.sim import SimConfig, run_gossip_sim
from repro.fleet import (
    ClockNode,
    ClockPeerServer,
    ClockRegistry,
    GossipConfig,
    LoopbackTransport,
    MeshCollectiveTransport,
    SocketTransport,
    anti_entropy_session,
    gossip_round,
)
from repro.fleet import registry as fr
from repro.fleet.transport.socket import TransportError
from repro.launch.mesh import make_fleet_mesh
from repro.launch.peers import PeerSpec, parse_peers
from repro.runtime.clock_runtime import ClockConfig, ClockRuntime

RNG = np.random.default_rng(21)

AUDIT = GossipConfig(policy=CausalPolicy(fp_threshold=1.0))


def _clock(row, k=3) -> bc.BloomClock:
    return bc.BloomClock(jnp.asarray(np.asarray(row), jnp.int32),
                         jnp.zeros((), jnp.int32), k)


def _ticked(c, events):
    for e in events:
        c = bc.tick(c, jnp.uint32(e >> 32), jnp.uint32(e & 0xFFFFFFFF))
    return c


def _fixture_fleet(m=128, k=3, seed=0):
    """Every status kind: ancestor / same / descendant / forked, plus a
    straggler-able laggard and a promoted (>u8 span) row."""
    rng = np.random.default_rng(seed)
    local = _ticked(bc.zeros(m, k), range(30))
    wide = np.zeros(m, np.int64)
    wide[3] = 700                      # span > 255: promoted row
    return {
        "anc": _ticked(bc.zeros(m, k), range(12)),
        "same": local,
        "desc": _ticked(local, range(200, 208)),
        "fork": _ticked(bc.zeros(m, k), range(900, 912)),
        "lag": _ticked(bc.zeros(m, k), range(2)),
        "wide": _clock(wide, k),
        "rand": _clock(rng.integers(0, 6, m), k),
    }, local


# ---------------------------------------------------------------------------
# wire robustness
# ---------------------------------------------------------------------------

def test_wire_roundtrip_u8_and_i32():
    for cells, base in [(np.arange(64) % 7, 3), (np.arange(64) * 100, 0)]:
        c = bc.BloomClock(jnp.asarray(cells, jnp.int32),
                          jnp.asarray(base, jnp.int32), 4)
        frame = wire.encode_clock(bc.to_wire(c))
        back = bc.from_wire(frame)
        np.testing.assert_array_equal(np.asarray(back.logical_cells()),
                                      np.asarray(c.logical_cells()))
        assert back.k == c.k


def test_wire_rejects_truncation_everywhere():
    frame = wire.encode_clock(bc.to_wire(_ticked(bc.zeros(64, 3), range(9))))
    for cut in (0, 1, 2, 5, 13, len(frame) // 2, len(frame) - 1):
        with pytest.raises(wire.WireFormatError, match="truncated"):
            wire.decode_clock(frame[:cut])


def test_wire_rejects_corruption_and_garbage():
    frame = wire.encode_clock(bc.to_wire(_ticked(bc.zeros(64, 3), range(9))))
    # flip one payload byte -> CRC catches it
    bad = bytearray(frame)
    bad[20] ^= 0x40
    with pytest.raises(wire.WireFormatError, match="CRC32 mismatch"):
        wire.decode_clock(bytes(bad))
    # trailing garbage is framing loss, not silently ignored
    with pytest.raises(wire.WireFormatError, match="oversized"):
        wire.decode_clock(frame + b"\x00")
    # wrong magic
    with pytest.raises(wire.WireFormatError, match="magic"):
        wire.decode_clock(b"ZZ" + frame[2:])


def test_wire_rejects_unknown_version_and_dtype():
    frame = bytearray(wire.encode_clock(
        bc.to_wire(_ticked(bc.zeros(64, 3), range(9)))))
    v2 = frame.copy()
    v2[2] = 9
    with pytest.raises(wire.WireFormatError, match="version 9"):
        wire.decode_clock(bytes(v2))
    dt = frame.copy()
    dt[3] = 7                          # unknown cell dtype code
    dt[-4:] = wire._CRC.pack(__import__("zlib").crc32(bytes(dt[:-4])))
    with pytest.raises(wire.WireFormatError, match="dtype code 7"):
        wire.decode_clock(bytes(dt))


def test_digest_roundtrip_and_robustness():
    d = wire.digest_of("node-7", np.arange(32), base=2, k=3)
    frame = wire.encode_digest(d)
    assert wire.decode_digest(frame) == d
    assert d.nbytes == len(frame)
    with pytest.raises(wire.WireFormatError, match="truncated"):
        wire.decode_digest(frame[:5])
    with pytest.raises(wire.WireFormatError, match="peer-id length"):
        wire.decode_digest(frame + b"xx")
    # a flipped header byte (the advertised clock sum) can't silently
    # steer a pull/skip decision
    bad = bytearray(frame)
    bad[10] ^= 0x10
    with pytest.raises(wire.WireFormatError, match="CRC32 mismatch"):
        wire.decode_digest(bytes(bad))
    # non-utf8 peer-id bytes (with a VALID checksum, i.e. an encoder
    # bug rather than line noise) surface as WireFormatError too
    import zlib
    garbled = bytearray(frame[:-4])
    garbled[wire._DIGEST_HDR.size] = 0xFF
    garbled += wire._CRC.pack(zlib.crc32(bytes(garbled)))
    with pytest.raises(wire.WireFormatError, match="not valid utf-8"):
        wire.decode_digest(bytes(garbled))


def test_cells_crc_is_representation_independent():
    logical = np.asarray([7, 9, 7, 8], np.int64)
    assert (wire.cells_crc(logical, 0)
            == wire.cells_crc(logical - 7, 7)
            == wire.cells_crc(logical.astype(np.uint8), 0))


# ---------------------------------------------------------------------------
# loopback bit-identity vs the pre-refactor gossip_round
# ---------------------------------------------------------------------------

def _pre_refactor_gossip_round(registry, local, fp_gate, straggler_gap,
                               push_back):
    """VERBATIM port of the pre-transport ``gossip_round`` body (PR 4
    state) — the behavioral pin the loopback session must match bit for
    bit on masks, merged cells, and Eq. 3 fp."""
    view = registry.classify_all(local)
    alive = view.alive
    quarantined = alive & (view.status == fr.FORKED)
    stragglers = np.zeros_like(alive)
    if alive.any():
        med = float(np.median(view.sums[alive]))
        stragglers = alive & ~quarantined & ((med - view.sums) > straggler_gap)
    comparable = alive & ~quarantined & ~stragglers
    unconfident = comparable & ~view.confident(fp_gate)
    accepted = comparable & ~unconfident
    merged = local
    if accepted.any():
        merged = registry.union(accepted, local)
        merged = bc.compress(merged)
        if push_back:
            registry.broadcast(accepted, merged)
    return merged, dict(accepted=accepted, quarantined=quarantined,
                        stragglers=stragglers, unconfident=unconfident,
                        view=view)


@pytest.mark.parametrize("gate,gap,push", [
    (1.0, np.inf, True),
    (1.0, 10.0, True),
    (1e-4, 64.0, False),
    (0.3, 64.0, True),
])
def test_loopback_session_bit_identical_to_pre_refactor(gate, gap, push):
    peers, local = _fixture_fleet()
    ref_reg = ClockRegistry(capacity=8, m=128, k=3)
    ref_reg.admit_many(peers)
    got_reg = ClockRegistry(capacity=8, m=128, k=3)
    got_reg.admit_many(peers)

    m_ref, r_ref = _pre_refactor_gossip_round(ref_reg, local, gate, gap, push)
    cfg = GossipConfig(policy=CausalPolicy(fp_threshold=gate),
                       straggler_gap=gap, push_back=push)
    m_got, r_got = gossip_round(got_reg, local, cfg)

    for mask in ("accepted", "quarantined", "stragglers", "unconfident"):
        np.testing.assert_array_equal(getattr(r_got, mask), r_ref[mask],
                                      err_msg=mask)
    np.testing.assert_array_equal(r_got.view.status, r_ref["view"].status)
    # fp BITS, not tolerances
    np.testing.assert_array_equal(r_got.view.fp, r_ref["view"].fp)
    np.testing.assert_array_equal(np.asarray(m_got.logical_cells()),
                                  np.asarray(m_ref.logical_cells()))
    assert r_got.transport == "loopback"
    assert r_got.digest_bytes == 0 and r_got.delta_bytes == 0
    # push-back cost is now MEASURED: n_accepted encoded frames
    if push and r_got.n_accepted:
        frame = wire.encode_clock(bc.to_wire(m_got))
        assert r_got.pushback_bytes == len(frame) * r_got.n_accepted
    # and the registries ended in the same state
    np.testing.assert_array_equal(np.asarray(got_reg.cells),
                                  np.asarray(ref_reg.cells))


def test_report_wire_fields_present_on_legacy_path():
    peers, local = _fixture_fleet()
    reg = ClockRegistry(capacity=8, m=128, k=3)
    reg.admit_many(peers)
    _, report = gossip_round(reg, local)
    assert report.wire_bytes == report.pushback_bytes
    assert "loopback" in report.summary()


# ---------------------------------------------------------------------------
# socket transport
# ---------------------------------------------------------------------------

@pytest.fixture
def socket_fleet():
    """Thread-served TCP fleet mirroring ``_fixture_fleet`` peer data."""
    peers, local = _fixture_fleet()
    nodes, servers, addresses = {}, [], {}
    for pid, c in peers.items():
        node = ClockNode(pid, 128, 3)
        node.set_cells(np.asarray(c.logical_cells()))
        server = ClockPeerServer(node).start()
        nodes[pid] = node
        servers.append(server)
        addresses[pid] = server.address
    yield peers, local, nodes, addresses
    for server in servers:
        server.stop()


def test_socket_session_matches_loopback_decisions(socket_fleet):
    peers, local, nodes, addresses = socket_fleet
    loop_reg = ClockRegistry(capacity=8, m=128, k=3)
    loop_reg.admit_many(peers)
    m_ref, r_ref = gossip_round(loop_reg, local, AUDIT)

    sock_reg = ClockRegistry(capacity=8, m=128, k=3)
    transport = SocketTransport(addresses)
    m_got, r_got = anti_entropy_session(sock_reg, local, transport, AUDIT)

    assert r_got.transport == "socket"
    assert r_got.digest_bytes > 0 and r_got.delta_bytes > 0
    # same per-peer verdicts and decisions (slot layouts may differ)
    for pid in peers:
        rs, gs = loop_reg.slot_of(pid), sock_reg.slot_of(pid)
        assert r_ref.view.status[rs] == r_got.view.status[gs], pid
        assert r_ref.view.fp[rs] == r_got.view.fp[gs], pid
        assert r_ref.accepted[rs] == r_got.accepted[gs], pid
        assert r_ref.quarantined[rs] == r_got.quarantined[gs], pid
    np.testing.assert_array_equal(np.asarray(m_got.logical_cells()),
                                  np.asarray(m_ref.logical_cells()))
    # push-back physically reached the accepted peers' processes
    for pid in peers:
        if r_got.accepted[sock_reg.slot_of(pid)]:
            np.testing.assert_array_equal(
                nodes[pid].cells(), np.asarray(m_got.logical_cells()), pid)


def test_socket_second_round_skips_converged_peers(socket_fleet):
    peers, local, nodes, addresses = socket_fleet
    reg = ClockRegistry(capacity=8, m=128, k=3)
    transport = SocketTransport(addresses)
    merged, first = anti_entropy_session(reg, local, transport, AUDIT)
    assert first.delta_bytes > 0
    merged2, second = anti_entropy_session(reg, merged, transport, AUDIT)
    # accepted peers converged to the union and were not re-pulled;
    # only peers the round did NOT push to (quarantined fork) still
    # advertise an unseen digest — and they were already ingested
    assert second.delta_bytes == 0
    assert second.digest_bytes == first.digest_bytes
    np.testing.assert_array_equal(np.asarray(merged2.logical_cells()),
                                  np.asarray(merged.logical_cells()))


def test_socket_rejects_corrupted_push(socket_fleet):
    peers, local, nodes, addresses = socket_fleet
    transport = SocketTransport(addresses)
    frame = bytearray(wire.encode_clock(bc.to_wire(local)))
    frame[18] ^= 0xFF
    before = nodes["anc"].cells()
    with pytest.raises(TransportError, match="CRC32 mismatch"):
        transport.push(["anc"], bytes(frame))
    np.testing.assert_array_equal(nodes["anc"].cells(), before)


def test_socket_rejects_wrong_m_push(socket_fleet):
    peers, local, nodes, addresses = socket_fleet
    transport = SocketTransport(addresses)
    wrong = wire.encode_clock(bc.to_wire(bc.zeros(32, 3)))
    with pytest.raises(TransportError, match="m=32"):
        transport.push(["anc"], wrong)


def test_gossip_sim_socket_transport_no_false_negatives():
    r = run_gossip_sim(
        SimConfig(n_nodes=5, n_events=120, m=64, k=3, seed=3),
        n_rounds=4, transport="socket")
    assert r.transport == "socket"
    assert r.false_negatives == 0
    assert r.within_eq3_band
    # wire costs are measured frame bytes, not models
    assert r.digest_bytes > 0 and r.delta_bytes > 0
    assert r.wire_bytes == r.digest_bytes + r.delta_bytes + r.pushback_bytes


# ---------------------------------------------------------------------------
# mesh-collective transport
# ---------------------------------------------------------------------------

def test_mesh_transport_needs_mesh():
    with pytest.raises(ValueError, match="mesh-sharded registry"):
        MeshCollectiveTransport(ClockRegistry(capacity=4, m=64, k=3))


def test_mesh_digest_ring_matches_slab(host_devices):
    peers, local = _fixture_fleet()
    reg = ClockRegistry(capacity=8, m=128, k=3, mesh=make_fleet_mesh(4))
    reg.admit_many(peers)
    transport = MeshCollectiveTransport(reg)
    digests, nbytes = transport.digests()
    assert nbytes > 0
    assert set(digests) == set(peers)
    sums = np.asarray(reg.sums)
    for pid, d in digests.items():
        slot = reg.slot_of(pid)
        assert d.clock_sum == pytest.approx(float(sums[slot]))
        assert d.m == 128 and d.k == 3


def test_mesh_session_matches_loopback(host_devices):
    peers, local = _fixture_fleet()
    ref_reg = ClockRegistry(capacity=8, m=128, k=3)
    ref_reg.admit_many(peers)
    m_ref, r_ref = gossip_round(ref_reg, local, AUDIT)
    for shards in (2, 4):
        reg = ClockRegistry(capacity=8, m=128, k=3,
                            mesh=make_fleet_mesh(shards))
        reg.admit_many(peers)
        m_got, r_got = anti_entropy_session(
            reg, local, MeshCollectiveTransport(reg), AUDIT)
        assert r_got.transport == "mesh" and r_got.shards == shards
        for mask in ("accepted", "quarantined", "stragglers", "unconfident"):
            np.testing.assert_array_equal(getattr(r_got, mask),
                                          getattr(r_ref, mask), err_msg=mask)
        np.testing.assert_array_equal(r_got.view.fp, r_ref.view.fp)
        np.testing.assert_array_equal(np.asarray(m_got.logical_cells()),
                                      np.asarray(m_ref.logical_cells()))
        assert r_got.pushback_bytes == r_ref.pushback_bytes
        assert r_got.digest_bytes > 0


def test_gossip_sim_mesh_transport_no_false_negatives(host_devices):
    factory = lambda cap, m, k: ClockRegistry(
        capacity=cap, m=m, k=k, mesh=make_fleet_mesh(4))
    r = run_gossip_sim(
        SimConfig(n_nodes=5, n_events=120, m=64, k=3, seed=3),
        n_rounds=4, registry_factory=factory, transport="mesh")
    assert r.transport == "mesh"
    assert r.false_negatives == 0
    assert r.digest_bytes > 0


# ---------------------------------------------------------------------------
# runtime + launch plumbing
# ---------------------------------------------------------------------------

def test_clock_runtime_gossip_default_loopback():
    rt = ClockRuntime(ClockConfig(m=128, k=3,
                                  policy=CausalPolicy(fp_threshold=1.0)))
    for i in range(20):
        rt.tick_step(i)
    reg = rt.make_registry(8)
    reg.admit_many({"behind": bc.zeros(128, 3), "ahead": _ticked(
        rt.clock, range(300, 304))})
    before = np.asarray(rt.clock.logical_cells())
    report = rt.gossip(reg)
    assert report.transport == "loopback"
    assert report.n_accepted == 2
    after = np.asarray(rt.clock.logical_cells())
    assert (after >= before).all() and after.sum() > before.sum()


def test_clock_runtime_gossip_over_socket(socket_fleet):
    peers, local, nodes, addresses = socket_fleet
    rt = ClockRuntime(ClockConfig(m=128, k=3,
                                  policy=CausalPolicy(fp_threshold=1.0)))
    rt.clock = local
    reg = rt.make_registry(8)
    report = rt.gossip(reg, transport=SocketTransport(addresses))
    assert report.transport == "socket"
    assert report.n_accepted > 0
    # the runtime clock absorbed the union
    for pid in peers:
        if report.accepted[reg.slot_of(pid)]:
            assert bool(bc.ordering(peers[pid], rt.clock).a_le_b)


def test_peer_spec_parsing():
    specs = parse_peers("a@127.0.0.1:9001, b@[::1]:9002")
    assert specs[0] == PeerSpec("a", "127.0.0.1", 9001)
    # brackets are syntax, not part of the connectable host
    assert specs[1] == PeerSpec("b", "::1", 9002)
    assert str(specs[0]) == "a@127.0.0.1:9001"
    with pytest.raises(ValueError, match="bad peer spec"):
        parse_peers("nope")
    with pytest.raises(ValueError, match="duplicate"):
        parse_peers("a@h:1,a@h:2")


def test_gossip_config_scalar_shim_warns_once_per_construction():
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        cfg = GossipConfig()                      # defaults: silent
        assert not caught
        legacy = GossipConfig(fp_threshold=0.5)   # explicit scalar: warns
    assert [w.category for w in caught] == [DeprecationWarning]
    assert cfg.fp_gate == 1e-4 and legacy.fp_gate == 0.5
    # dataclasses.replace re-runs the shim (frozen config stays frozen)
    assert dataclasses.replace(AUDIT, straggler_gap=1.0).fp_gate == 1.0
