"""Bit-identity pins for the template-emitted compare-kernel family.

``kernels.template`` + ``kernels.generate`` replaced the hand-rolled
engine bodies that used to live in ``kernels.bloom_matrix``.  The
contract of that refactor is exact: every emitted instance must produce
byte-for-byte the outputs (flags, sums, Eq. 3 fp bits, dtypes) of the
kernel it replaced.  This module carries VERBATIM copies of the deleted
pre-refactor kernels (prefixed ``_legacy_``) and pins each instance
against them, so any drift in the template — reordered ops, a changed
accumulate dtype, a different Eq. 3 expression — fails here even if the
result stays semantically "correct".

Also pinned: the generator's refusal of malformed specs and of knob
combinations whose analytic VMEM estimate exceeds the backend budget,
and (property tests) end-to-end agreement of every engine x pack mode
with the broadcast reference ``comparability_matrix``.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import causal
from repro.core import clock as bc
from repro.kernels import pack
from repro.kernels.generate import (
    ENGINE_SPECS,
    bloom_matrix_mxu_pallas,
    bloom_matrix_packed_pallas,
    bloom_matrix_pallas,
    bloom_matrix_tri_pallas,
    bloom_one_vs_many_packed_pallas,
    bloom_one_vs_many_pallas,
)
from repro.kernels.template import (
    VMEM_BUDGET,
    CompareSpec,
    emit,
    validate,
    vmem_estimate,
)

RNG = np.random.default_rng(77)


# ---------------------------------------------------------------------------
# VERBATIM pre-refactor kernels (deleted from bloom_matrix.py in PR 7).
# Do not "fix" or modernize these — they are the reference the template
# is pinned against.
# ---------------------------------------------------------------------------

def _legacy_one_vs_many_kernel(
    q_ref, p_ref,
    flags_ref, sums_ref, fp_ref,
    *, n_mtiles: int, m: int,
):
    j = pl.program_id(1)
    q = q_ref[...]
    p = p_ref[...]

    le = jnp.all(q <= p, axis=1, keepdims=True)
    ge = jnp.all(q >= p, axis=1, keepdims=True)
    sp = jnp.sum(p, axis=1, keepdims=True).astype(jnp.float32)
    sq = jnp.broadcast_to(
        jnp.sum(q, axis=1, keepdims=True).astype(jnp.float32), sp.shape)

    @pl.when(j == 0)
    def _init():
        flags_ref[...] = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        sums_ref[...] = jnp.concatenate([sq, sp], axis=1)

    @pl.when(j > 0)
    def _acc():
        cur = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        flags_ref[...] = flags_ref[...] & cur
        sums_ref[...] = sums_ref[...] + jnp.concatenate([sq, sp], axis=1)

    @pl.when(j == n_mtiles - 1)
    def _finalize():
        s = sums_ref[...]
        log_q = jnp.log1p(-1.0 / m)
        inner_p = jnp.clip(-jnp.expm1(s[:, 1:2] * log_q), 1e-30, 1.0)
        inner_q = jnp.clip(-jnp.expm1(s[:, 0:1] * log_q), 1e-30, 1.0)
        fp_qp = jnp.exp(s[:, 0:1] * jnp.log(inner_p))
        fp_pq = jnp.exp(s[:, 1:2] * jnp.log(inner_q))
        fp_ref[...] = jnp.concatenate([fp_qp, fp_pq], axis=1)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "m_true", "interpret"))
def _legacy_one_vs_many_pallas(q, peers, *, bn=8, bm=512, m_true=None,
                               interpret=False):
    N, m = peers.shape
    assert q.shape == (1, m) and m % bm == 0 and N % bn == 0
    n_mtiles = m // bm
    kernel = functools.partial(
        _legacy_one_vs_many_kernel, n_mtiles=n_mtiles,
        m=m_true if m_true else m)
    return pl.pallas_call(
        kernel,
        grid=(N // bn, n_mtiles),
        in_specs=[
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 2), jnp.int32),
            jax.ShapeDtypeStruct((N, 2), jnp.float32),
            jax.ShapeDtypeStruct((N, 2), jnp.float32),
        ],
        interpret=interpret,
    )(q, peers)


def _legacy_matrix_kernel(
    a_ref, b_ref, bsums_ref,
    le_ref, ge_ref, asums_ref, fp_ref,
    *, n_mtiles: int, m: int,
):
    j = pl.program_id(1)
    jm = pl.program_id(2)
    a = a_ref[...]
    b = b_ref[...]

    le = jnp.all(a[:, None, :] <= b[None, :, :], axis=2)
    ge = jnp.all(a[:, None, :] >= b[None, :, :], axis=2)
    sa = jnp.sum(a, axis=1, keepdims=True).astype(jnp.float32)

    @pl.when(jnp.logical_and(j == 0, jm == 0))
    def _init_sums():
        asums_ref[...] = sa

    @pl.when(jnp.logical_and(j == 0, jm > 0))
    def _acc_sums():
        asums_ref[...] = asums_ref[...] + sa

    @pl.when(jm == 0)
    def _init_flags():
        le_ref[...] = le.astype(jnp.int32)
        ge_ref[...] = ge.astype(jnp.int32)

    @pl.when(jm > 0)
    def _acc_flags():
        le_ref[...] = le_ref[...] & le.astype(jnp.int32)
        ge_ref[...] = ge_ref[...] & ge.astype(jnp.int32)

    @pl.when(jm == n_mtiles - 1)
    def _finalize():
        sa_tot = asums_ref[...]
        sb_tot = bsums_ref[...]
        log_q = jnp.log1p(-1.0 / m)
        inner_b = jnp.clip(-jnp.expm1(sb_tot * log_q), 1e-30, 1.0)
        fp_ref[...] = jnp.exp(sa_tot * jnp.log(inner_b))


@functools.partial(
    jax.jit, static_argnames=("bi", "bj", "bm", "m_true", "interpret"))
def _legacy_matrix_pallas(rows, cols, col_sums, *, bi=8, bj=128, bm=512,
                          m_true=None, interpret=False):
    N, m = rows.shape
    M, mc = cols.shape
    assert m == mc and col_sums.shape == (1, M)
    assert N % bi == 0 and M % bj == 0 and m % bm == 0
    n_mtiles = m // bm
    kernel = functools.partial(
        _legacy_matrix_kernel, n_mtiles=n_mtiles, m=m_true if m_true else m)
    return pl.pallas_call(
        kernel,
        grid=(N // bi, M // bj, n_mtiles),
        in_specs=[
            pl.BlockSpec((bi, bm), lambda i, j, jm: (i, jm)),
            pl.BlockSpec((bj, bm), lambda i, j, jm: (j, jm)),
            pl.BlockSpec((1, bj), lambda i, j, jm: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
            pl.BlockSpec((bi, 1), lambda i, j, jm: (i, 0)),
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, M), jnp.int32),
            jax.ShapeDtypeStruct((N, M), jnp.int32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
            jax.ShapeDtypeStruct((N, M), jnp.float32),
        ],
        interpret=interpret,
    )(rows, cols, col_sums)


def _legacy_pair_flags_minmax(a_ref, b_ref, abase_ref, bbase_ref,
                              *, with_base, m_true, bm, jm):
    a = a_ref[...]
    b = b_ref[...]
    d = a.astype(jnp.int16)[:, None, :] - b.astype(jnp.int16)[None, :, :]
    if with_base:
        delta = jnp.clip(abase_ref[...] - bbase_ref[...].T, -256, 256)
        d = d + delta[:, :, None].astype(jnp.int16)
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, bm), 2) + jm * bm
        d = jnp.where(col < m_true, d, 0)
    le = (jnp.max(d, axis=2) <= 0).astype(jnp.int8)
    ge = (jnp.min(d, axis=2) >= 0).astype(jnp.int8)
    return le, ge


def _legacy_flags_kernel_step(refs, *, jm, with_base, m_true, bm):
    if with_base:
        a_ref, b_ref, abase_ref, bbase_ref, le_ref, ge_ref = refs
    else:
        a_ref, b_ref, le_ref, ge_ref = refs
        abase_ref = bbase_ref = None
    le, ge = _legacy_pair_flags_minmax(a_ref, b_ref, abase_ref, bbase_ref,
                                       with_base=with_base, m_true=m_true,
                                       bm=bm, jm=jm)

    @pl.when(jm == 0)
    def _init():
        le_ref[...] = le
        ge_ref[...] = ge

    @pl.when(jm > 0)
    def _acc():
        le_ref[...] = le_ref[...] & le
        ge_ref[...] = ge_ref[...] & ge


def _legacy_tri_kernel(ti_ref, tj_ref, *refs, n_mtiles, with_base,
                       m_true, bm):
    _legacy_flags_kernel_step(refs, jm=pl.program_id(1),
                              with_base=with_base, m_true=m_true, bm=bm)


@functools.partial(
    jax.jit, static_argnames=("bi", "bm", "m_true", "with_base", "interpret"))
def _legacy_tri_pallas(cells, base, *, bi=128, bm=512, m_true=None,
                       with_base=False, interpret=False):
    N, m = cells.shape
    assert N % bi == 0 and m % bm == 0, (N, m, bi, bm)
    k = N // bi
    tri = [(i, j) for i in range(k) for j in range(i, k)]
    ti = jnp.asarray([i for i, _ in tri], jnp.int32)
    tj = jnp.asarray([j for _, j in tri], jnp.int32)
    n_mtiles = m // bm
    kernel = functools.partial(
        _legacy_tri_kernel, n_mtiles=n_mtiles, with_base=with_base,
        m_true=m_true if m_true else m, bm=bm)
    in_specs = [
        pl.BlockSpec((bi, bm), lambda t, jm, ti, tj: (ti[t], jm)),
        pl.BlockSpec((bi, bm), lambda t, jm, ti, tj: (tj[t], jm)),
    ]
    operands = [cells, cells]
    if with_base:
        in_specs += [
            pl.BlockSpec((bi, 1), lambda t, jm, ti, tj: (ti[t], 0)),
            pl.BlockSpec((bi, 1), lambda t, jm, ti, tj: (tj[t], 0)),
        ]
        operands += [base, base]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(len(tri), n_mtiles),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bi, bi), lambda t, jm, ti, tj: (ti[t], tj[t])),
            pl.BlockSpec((bi, bi), lambda t, jm, ti, tj: (ti[t], tj[t])),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((N, N), jnp.int8),
            jax.ShapeDtypeStruct((N, N), jnp.int8),
        ],
        interpret=interpret,
    )(ti, tj, *operands)


def _legacy_packed_kernel(*refs, n_mtiles, with_base, m_true, bm):
    _legacy_flags_kernel_step(refs, jm=pl.program_id(2),
                              with_base=with_base, m_true=m_true, bm=bm)


@functools.partial(
    jax.jit,
    static_argnames=("bi", "bj", "bm", "m_true", "with_base", "interpret"))
def _legacy_packed_pallas(rows, cols, row_base, col_base, *, bi=128, bj=128,
                          bm=512, m_true=None, with_base=False,
                          interpret=False):
    N, m = rows.shape
    M, mc = cols.shape
    assert m == mc and N % bi == 0 and M % bj == 0 and m % bm == 0
    n_mtiles = m // bm
    kernel = functools.partial(
        _legacy_packed_kernel, n_mtiles=n_mtiles, with_base=with_base,
        m_true=m_true if m_true else m, bm=bm)
    in_specs = [
        pl.BlockSpec((bi, bm), lambda i, j, jm: (i, jm)),
        pl.BlockSpec((bj, bm), lambda i, j, jm: (j, jm)),
    ]
    operands = [rows, cols]
    if with_base:
        in_specs += [
            pl.BlockSpec((bi, 1), lambda i, j, jm: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i, j, jm: (j, 0)),
        ]
        operands += [row_base, col_base]
    return pl.pallas_call(
        kernel,
        grid=(N // bi, M // bj, n_mtiles),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, M), jnp.int8),
            jax.ShapeDtypeStruct((N, M), jnp.int8),
        ],
        interpret=interpret,
    )(*operands)


def _legacy_mxu_kernel(a_ref, b_ref, abase_ref, bbase_ref, viol_ref,
                       *, n_mtiles, n_thresholds, lo, m_true, bm):
    jm = pl.program_id(2)
    av = a_ref[...].astype(jnp.int32) + (abase_ref[...] - lo)
    bv = b_ref[...].astype(jnp.int32) + (bbase_ref[...] - lo)
    col = jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1) + jm * bm
    av = jnp.where(col < m_true, av, -1)
    bv = jnp.where(col < m_true, bv, n_thresholds + 1)
    thr = jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, n_thresholds), 2) + 1
    bi_, bj_ = av.shape[0], bv.shape[0]
    enc_a = (av[:, :, None] >= thr).reshape(bi_, -1).astype(jnp.float32)
    enc_b = (bv[:, :, None] < thr).reshape(bj_, -1).astype(jnp.float32)
    v = jax.lax.dot_general(
        enc_a, enc_b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(jm == 0)
    def _init():
        viol_ref[...] = v

    @pl.when(jm > 0)
    def _acc():
        viol_ref[...] = viol_ref[...] + v


@functools.partial(
    jax.jit,
    static_argnames=("bi", "bj", "bm", "n_thresholds", "lo", "m_true",
                     "interpret"))
def _legacy_mxu_pallas(rows, cols, row_base, col_base, *, n_thresholds, lo,
                       bi=128, bj=128, bm=128, m_true=None, interpret=False):
    N, m = rows.shape
    M, mc = cols.shape
    assert m == mc and N % bi == 0 and M % bj == 0 and m % bm == 0
    assert (m_true if m_true else m) * n_thresholds < 2**24
    n_mtiles = m // bm
    kernel = functools.partial(
        _legacy_mxu_kernel, n_mtiles=n_mtiles,
        n_thresholds=n_thresholds, lo=lo,
        m_true=m_true if m_true else m, bm=bm)
    return pl.pallas_call(
        kernel,
        grid=(N // bi, M // bj, n_mtiles),
        in_specs=[
            pl.BlockSpec((bi, bm), lambda i, j, jm: (i, jm)),
            pl.BlockSpec((bj, bm), lambda i, j, jm: (j, jm)),
            pl.BlockSpec((bi, 1), lambda i, j, jm: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i, j, jm: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), jnp.float32),
        interpret=interpret,
    )(rows, cols, row_base, col_base)


def _legacy_one_vs_many_packed_kernel(
    q_ref, p_ref, pbase_ref,
    flags_ref, sums_ref, fp_ref,
    *, n_mtiles: int, m: int, bm: int,
):
    j = pl.program_id(1)
    q = q_ref[...]
    p = p_ref[...].astype(jnp.int32) + pbase_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1) + j * bm
    p = jnp.where(col < m, p, 0)

    le = jnp.all(q <= p, axis=1, keepdims=True)
    ge = jnp.all(q >= p, axis=1, keepdims=True)
    sp = jnp.sum(p, axis=1, keepdims=True).astype(jnp.float32)
    sq = jnp.broadcast_to(
        jnp.sum(q, axis=1, keepdims=True).astype(jnp.float32), sp.shape)

    @pl.when(j == 0)
    def _init():
        flags_ref[...] = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        sums_ref[...] = jnp.concatenate([sq, sp], axis=1)

    @pl.when(j > 0)
    def _acc():
        cur = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        flags_ref[...] = flags_ref[...] & cur
        sums_ref[...] = sums_ref[...] + jnp.concatenate([sq, sp], axis=1)

    @pl.when(j == n_mtiles - 1)
    def _finalize():
        s = sums_ref[...]
        log_q = jnp.log1p(-1.0 / m)
        inner_p = jnp.clip(-jnp.expm1(s[:, 1:2] * log_q), 1e-30, 1.0)
        inner_q = jnp.clip(-jnp.expm1(s[:, 0:1] * log_q), 1e-30, 1.0)
        fp_qp = jnp.exp(s[:, 0:1] * jnp.log(inner_p))
        fp_pq = jnp.exp(s[:, 1:2] * jnp.log(inner_q))
        fp_ref[...] = jnp.concatenate([fp_qp, fp_pq], axis=1)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "m_true", "interpret"))
def _legacy_one_vs_many_packed_pallas(q, peers, base, *, bn=8, bm=512,
                                      m_true=None, interpret=False):
    N, m = peers.shape
    assert q.shape == (1, m) and m % bm == 0 and N % bn == 0
    n_mtiles = m // bm
    kernel = functools.partial(
        _legacy_one_vs_many_packed_kernel, n_mtiles=n_mtiles,
        m=m_true if m_true else m, bm=bm)
    return pl.pallas_call(
        kernel,
        grid=(N // bn, n_mtiles),
        in_specs=[
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 2), jnp.int32),
            jax.ShapeDtypeStruct((N, 2), jnp.float32),
            jax.ShapeDtypeStruct((N, 2), jnp.float32),
        ],
        interpret=interpret,
    )(q, peers, base)


# ---------------------------------------------------------------------------
# shared random inputs
# ---------------------------------------------------------------------------

N, M, m = 16, 24, 256
BI, BJ, BM = 8, 8, 128


def _packed_inputs():
    rows = jnp.asarray(RNG.integers(0, 200, (N, m)), jnp.uint8)
    cols = jnp.asarray(RNG.integers(0, 200, (M, m)), jnp.uint8)
    rb = jnp.asarray(RNG.integers(0, 5, (N, 1)), jnp.int32)
    cb = jnp.asarray(RNG.integers(0, 5, (M, 1)), jnp.int32)
    return rows, cols, rb, cb


def _assert_bit_identical(got, want, label):
    got = got if isinstance(got, (tuple, list)) else (got,)
    want = want if isinstance(want, (tuple, list)) else (want,)
    assert len(got) == len(want)
    for k, (g, w) in enumerate(zip(got, want)):
        assert g.dtype == w.dtype, (label, k, g.dtype, w.dtype)
        assert g.shape == w.shape, (label, k, g.shape, w.shape)
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w), err_msg=f"{label} output {k}")


# ---------------------------------------------------------------------------
# the pins: emitted instance == verbatim legacy kernel, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_base", [False, True])
def test_template_rect_pins_legacy(with_base):
    rows, cols, rb, cb = _packed_inputs()
    kw = dict(bi=BI, bj=BJ, bm=BM, m_true=m - 3, with_base=with_base,
              interpret=True)
    _assert_bit_identical(
        bloom_matrix_packed_pallas(rows, cols, rb, cb, **kw),
        _legacy_packed_pallas(rows, cols, rb, cb, **kw),
        f"rect(with_base={with_base})")


@pytest.mark.parametrize("with_base", [False, True])
def test_template_tri_pins_legacy(with_base):
    rows, _, rb, _ = _packed_inputs()
    kw = dict(bi=BI, bm=BM, m_true=m - 3, with_base=with_base,
              interpret=True)
    _assert_bit_identical(
        bloom_matrix_tri_pallas(rows, rb, **kw),
        _legacy_tri_pallas(rows, rb, **kw),
        f"tri(with_base={with_base})")


def test_template_mxu_pins_legacy():
    _, _, rb, cb = _packed_inputs()
    rows = jnp.asarray(RNG.integers(0, 30, (N, m)), jnp.uint8)
    cols = jnp.asarray(RNG.integers(0, 30, (M, m)), jnp.uint8)
    kw = dict(n_thresholds=40, lo=0, bi=BI, bj=BJ, bm=BM, m_true=m - 3,
              interpret=True)
    _assert_bit_identical(
        bloom_matrix_mxu_pallas(rows, cols, rb, cb, **kw),
        _legacy_mxu_pallas(rows, cols, rb, cb, **kw),
        "mxu")


def test_template_i32_stats_pins_legacy():
    rows = jnp.asarray(RNG.integers(0, 9, (N, m)), jnp.int32)
    cols = jnp.asarray(RNG.integers(0, 9, (M, m)), jnp.int32)
    col_sums = jnp.sum(cols, axis=1, dtype=jnp.float32)[None, :]
    kw = dict(bi=BI, bj=BJ, bm=BM, m_true=m, interpret=True)
    _assert_bit_identical(
        bloom_matrix_pallas(rows, cols, col_sums, **kw),
        _legacy_matrix_pallas(rows, cols, col_sums, **kw),
        "i32-stats")


def test_template_one_vs_many_i32_pins_legacy():
    peers = jnp.asarray(RNG.integers(0, 9, (N, m)), jnp.int32)
    q = jnp.asarray(RNG.integers(0, 9, (1, m)), jnp.int32)
    kw = dict(bn=8, bm=BM, m_true=m, interpret=True)
    _assert_bit_identical(
        bloom_one_vs_many_pallas(q, peers, **kw),
        _legacy_one_vs_many_pallas(q, peers, **kw),
        "one_vs_many-i32")


def test_template_one_vs_many_packed_pins_legacy():
    rows, _, rb, _ = _packed_inputs()
    q = jnp.asarray(RNG.integers(0, 9, (1, m)), jnp.int32)
    kw = dict(bn=8, bm=BM, m_true=m - 3, interpret=True)
    _assert_bit_identical(
        bloom_one_vs_many_packed_pallas(q, rows, rb, **kw),
        _legacy_one_vs_many_packed_pallas(q, rows, rb, **kw),
        "one_vs_many-packed")


def test_engine_specs_all_valid_and_distinct():
    seen = set()
    for name, spec in ENGINE_SPECS.items():
        validate(spec)                       # structural
        validate(spec, "interpret")          # and within the CI budget
        assert emit(spec) is emit(spec), name  # emission is cached
        assert spec not in seen, f"duplicate spec behind {name}"
        seen.add(spec)


# ---------------------------------------------------------------------------
# generator refusals: malformed specs and VMEM-over-budget knob combos
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(topology="hex"),
    dict(topology="rect", pack="f16"),
    dict(topology="tri", pack="i32"),
    dict(topology="rect", acc="f64"),
    dict(topology="rect", bi=12),                       # not sublane-aligned
    dict(topology="rect", bm=100),                      # not lane-aligned
    dict(topology="rect", pipeline_depth=0),
    dict(topology="mxu"),                               # T missing
    dict(topology="mxu", n_thresholds=8, with_stats=True),
    dict(topology="rect", n_thresholds=8),              # T is mxu-only
    dict(topology="one_vs_many"),                       # stats mandatory
    dict(topology="rect", pack="i32"),                  # stats mandatory
    dict(topology="rect", pack="u8", with_stats=True),
])
def test_generator_refuses_malformed_specs(bad):
    with pytest.raises(ValueError):
        emit(CompareSpec(**bad))


def test_generator_refuses_vmem_over_budget():
    # fine structurally, but the int16 difference alone is ~1 GiB: over
    # budget on EVERY backend
    spec = CompareSpec(topology="rect", bi=1024, bj=1024, bm=512)
    assert vmem_estimate(spec) > VMEM_BUDGET["interpret"]
    with pytest.raises(ValueError, match="VMEM estimate"):
        validate(spec, "interpret")
    # emission alone is legal (structure is fine) — the refusal fires
    # when the instance is invoked on a concrete backend
    fn = emit(spec)
    rows = jnp.zeros((1024, 512), jnp.uint8)
    with pytest.raises(ValueError, match="VMEM estimate"):
        fn(rows, rows, None, None, interpret=True)


def test_vmem_estimate_orders_backends_and_depths():
    small = CompareSpec(topology="rect", bi=8, bj=8, bm=128)
    big = CompareSpec(topology="rect", bi=256, bj=256, bm=512)
    assert vmem_estimate(small) < vmem_estimate(big)
    deeper = CompareSpec(topology="rect", bi=8, bj=8, bm=128,
                         pipeline_depth=3)
    assert vmem_estimate(deeper) > vmem_estimate(small)
    # the tpu budget is the binding one
    assert VMEM_BUDGET["tpu"] < VMEM_BUDGET["interpret"]
    validate(small, "tpu")
    with pytest.raises(ValueError, match="VMEM estimate"):
        validate(big, "tpu")


# ---------------------------------------------------------------------------
# property tests: emitted engines vs the broadcast reference
# ---------------------------------------------------------------------------

def _reference(logical):
    n = logical.shape[0]
    return bc.comparability_matrix(
        bc.BloomClock(logical, jnp.zeros((n,), jnp.int32), 3))


@pytest.mark.parametrize("engine", ["tri", "full", "mxu", "i32"])
def test_emitted_engines_match_reference_property(engine):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(3, 17), mm=st.integers(8, 130),
           seed=st.integers(0, 2**16))
    def check(n, mm, seed):
        rng = np.random.default_rng(seed)
        resid = jnp.asarray(rng.integers(0, 9, (n, mm)), jnp.int32)
        bases = jnp.asarray(rng.integers(0, 5, (n,)), jnp.int32)
        logical = resid + bases[:, None]
        u8, pb, ok = pack.pack_rows(resid, bases)
        assert bool(ok.all())
        ref = _reference(logical)
        got = causal.CausalEngine().pairs(
            causal.PackedSlab(u8, pb), engine=engine)
        np.testing.assert_array_equal(
            np.asarray(got["a_le_b"]), np.asarray(ref["a_le_b"]))
        np.testing.assert_array_equal(
            np.asarray(got["concurrent"]), np.asarray(ref["concurrent"]))

    check()


@pytest.mark.parametrize("pack_mode", ["u8", "i32"])
@pytest.mark.parametrize("engine", ["tri", "full", "mxu"])
def test_emitted_engines_match_reference_deterministic(engine, pack_mode):
    """Always-on (no hypothesis) cross-product: engine x pack mode."""
    rng = np.random.default_rng(5)
    n, mm = 13, 100
    resid = jnp.asarray(rng.integers(0, 9, (n, mm)), jnp.int32)
    bases = jnp.asarray(rng.integers(0, 5, (n,)), jnp.int32)
    logical = resid + bases[:, None]
    ref = _reference(logical)
    if pack_mode == "u8":
        u8, pb, ok = pack.pack_rows(resid, bases)
        assert bool(ok.all())
        slab = causal.PackedSlab(u8, pb)
        got = causal.CausalEngine().pairs(slab, engine=engine)
    else:
        if engine == "mxu":
            pytest.skip("mxu is a packed-only engine")
        got = causal.CausalEngine().pairs(logical, engine=engine)
    np.testing.assert_array_equal(
        np.asarray(got["a_le_b"]), np.asarray(ref["a_le_b"]))
    np.testing.assert_array_equal(
        np.asarray(got["b_le_a"]), np.asarray(ref["a_le_b"]).T)
    np.testing.assert_array_equal(
        np.asarray(got["concurrent"]), np.asarray(ref["concurrent"]))
