"""Adversarial wire-decode fuzz: hostile bytes never become a clock.

The §3 zero-false-negative guarantee is only as strong as the decode
layer: a truncated or bit-flipped frame that silently decoded to a
DIFFERENT clock would corrupt a registry row and fake a causal verdict.
So the contract under test is absolute — for every frame shape the §4
quantizer can emit (u8-packed, promoted int32, near-wrap / wrapped
bases, boundary residual spans):

- every strict prefix (truncation at EVERY offset) raises
  ``WireFormatError``;
- every single-bit flip, anywhere in the frame, raises (CRC32 detects
  all single-bit errors; the magic/version/length checks catch the
  rest);
- version skew raises even with a correctly recomputed CRC — a frame
  from a future build is rejected, not misparsed;
- trailing garbage and random byte soup raise;
- a mutation may only ever decode to the ORIGINAL clock, bit for bit.

Deterministic and dependency-free (repo idiom: the hypothesis property
sweeps live in tests/test_wire_properties.py and skip when hypothesis
is absent; these always run).
"""
import struct
import zlib

import numpy as np
import pytest

from repro.core import wire

INT32_MAX = np.iinfo(np.int32).max
_RNG = np.random.default_rng(0xB10C)

# every §4 representation + the boundary frames the chaos harness bends:
# min-m, full residual span, near-wrap and wrapped bases, promoted int32
SNAPSHOTS = {
    "u8_min_m": {"cells": _RNG.integers(0, 6, 4).astype(np.uint8),
                 "base": 0, "k": 3},
    "u8_span255": {"cells": np.array([0, 255] * 8, np.uint8),
                   "base": 7, "k": 4},
    "u8_near_wrap_base": {"cells": _RNG.integers(0, 9, 64).astype(np.uint8),
                          "base": INT32_MAX - 3, "k": 3},
    "u8_wrapped_base": {"cells": _RNG.integers(0, 9, 64).astype(np.uint8),
                        "base": -(2**31) + 5, "k": 3},
    "i32_promoted": {"cells": _RNG.integers(0, 5000, 96).astype(np.int32),
                     "base": 0, "k": 4},
    "i32_hot_rim": {"cells": (_RNG.integers(0, 50, 16)
                              + INT32_MAX - 60).astype(np.int32),
                    "base": 0, "k": 3},
}
NAMES = sorted(SNAPSHOTS)


def _frame(name):
    return wire.encode_clock(SNAPSHOTS[name])


def _assert_decodes_original(buf, name):
    snap = SNAPSHOTS[name]
    got = wire.decode_clock(buf)
    assert np.array_equal(got["cells"], np.asarray(snap["cells"]))
    assert got["base"] == wire._wrap_i32(snap["base"])
    assert got["k"] == snap["k"]


def _reseal(body: bytes) -> bytes:
    """Recompute the CRC trailer over a mutated body (an adversary who
    keeps the checksum honest must still be stopped by the semantic
    checks)."""
    return body + struct.pack("!I", zlib.crc32(body))


@pytest.mark.parametrize("name", NAMES)
def test_roundtrip_reference(name):
    _assert_decodes_original(_frame(name), name)


@pytest.mark.parametrize("name", NAMES)
def test_every_truncation_rejects(name):
    frame = _frame(name)
    for cut in range(len(frame)):
        with pytest.raises(wire.WireFormatError):
            wire.decode_clock(frame[:cut])


@pytest.mark.parametrize("name", NAMES)
def test_every_single_bitflip_rejects(name):
    frame = _frame(name)
    buf = bytearray(frame)
    for pos in range(len(frame)):
        for bit in range(8):
            buf[pos] ^= 1 << bit
            with pytest.raises(wire.WireFormatError):
                wire.decode_clock(bytes(buf))
            buf[pos] ^= 1 << bit
    assert bytes(buf) == frame          # restored; still decodes
    _assert_decodes_original(frame, name)


@pytest.mark.parametrize("name", NAMES)
def test_version_skew_rejects_even_with_valid_crc(name):
    frame = _frame(name)
    for ver in (0, wire.WIRE_VERSION + 1, 17, 127, 255):
        body = bytearray(frame[:-4])
        body[2] = ver
        with pytest.raises(wire.WireFormatError, match="version"):
            wire.decode_clock(_reseal(bytes(body)))


def test_unknown_dtype_code_rejects_even_with_valid_crc():
    frame = _frame("u8_min_m")
    for code in (2, 3, 9, 255):
        body = bytearray(frame[:-4])
        body[3] = code
        with pytest.raises(wire.WireFormatError):
            wire.decode_clock(_reseal(bytes(body)))


@pytest.mark.parametrize("name", NAMES)
def test_trailing_garbage_rejects(name):
    frame = _frame(name)
    for tail in (b"\x00", b"\xff" * 7, _frame(name)):
        with pytest.raises(wire.WireFormatError, match="oversized"):
            wire.decode_clock(frame + tail)


def test_random_byte_soup_never_decodes():
    rng = np.random.default_rng(42)
    for _ in range(300):
        n = int(rng.integers(0, 600))
        with pytest.raises(wire.WireFormatError):
            wire.decode_clock(rng.integers(0, 256, n,
                                           dtype=np.uint8).tobytes())


@pytest.mark.parametrize("name", NAMES)
def test_multibyte_corruption_never_yields_a_different_clock(name):
    """Random multi-byte stompings: reject, or (if the mutation was a
    no-op round-trip) decode to the untouched original — NEVER to a
    third clock."""
    frame = _frame(name)
    rng = np.random.default_rng(7)
    for _ in range(200):
        buf = bytearray(frame)
        for _ in range(int(rng.integers(1, 6))):
            buf[int(rng.integers(0, len(buf)))] = int(rng.integers(0, 256))
        mutated = bytes(buf)
        try:
            got = wire.decode_clock(mutated)
        except wire.WireFormatError:
            continue
        assert mutated == frame
        assert np.array_equal(got["cells"],
                              np.asarray(SNAPSHOTS[name]["cells"]))


# -- digest frames: same contract, a corrupted digest must not steer a
#    wrong pull/skip decision --------------------------------------------

def _digest_frame():
    return wire.encode_digest(
        wire.digest_of("peer-7", np.arange(33), base=INT32_MAX - 9, k=4))


def test_digest_truncation_and_bitflips_reject():
    frame = _digest_frame()
    ref = wire.decode_digest(frame)
    for cut in range(len(frame)):
        with pytest.raises(wire.WireFormatError):
            wire.decode_digest(frame[:cut])
    buf = bytearray(frame)
    for pos in range(len(frame)):
        for bit in range(8):
            buf[pos] ^= 1 << bit
            with pytest.raises(wire.WireFormatError):
                wire.decode_digest(bytes(buf))
            buf[pos] ^= 1 << bit
    assert wire.decode_digest(bytes(buf)) == ref


def test_digest_version_skew_rejects_with_valid_crc():
    frame = _digest_frame()
    body = bytearray(frame[:-4])
    body[2] = wire.WIRE_VERSION + 1
    with pytest.raises(wire.WireFormatError, match="version"):
        wire.decode_digest(_reseal(bytes(body)))


def test_clock_and_digest_frames_do_not_cross_decode():
    with pytest.raises(wire.WireFormatError, match="magic"):
        wire.decode_digest(_frame("u8_min_m"))
    with pytest.raises(wire.WireFormatError, match="magic"):
        wire.decode_clock(_digest_frame())
