"""Unified causality API validation.

Pins the acceptance contract of the front-door redesign:

- ``CausalEngine.classify`` / ``.pairs`` outputs are BIT-IDENTICAL
  (flags, Eq. 3 fp bits, sums) to the pre-refactor entry points across
  every engine path — int32 fallback, packed triangle, MXU thermometer,
  promoted-row overlay, and sharded {1, 2, 3, 4, 8} (3 exercises the
  odd-d mirror shipping of the halved ppermute ring);
- the old ``ops.*`` / ``core.clock.compare`` signatures remain
  importable as DeprecationWarning shims that delegate bit-identically,
  and NO internal ``repro.*`` caller still routes through them;
- the typed results are real pytrees: flatten/unflatten round-trips
  under jit, vmap, and device_put onto a sharded mesh;
- ``Comparison.confident(t)`` is equivalent to the pre-existing
  ``happened_before(a, b, threshold=t)`` decision rule (hypothesis).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import causal
from repro.core import clock as bc
from repro.fleet import ClockRegistry, GossipConfig, fleet_health, gossip_round
from repro.kernels import ops, pack
from repro.launch.mesh import make_fleet_mesh
from repro.runtime.clock_runtime import ClockConfig, ClockRuntime

RNG = np.random.default_rng(42)
MATRIX_KEYS = ("a_le_b", "b_le_a", "concurrent", "fp", "row_sums", "col_sums")
CLASSIFY_KEYS = ("q_le_p", "p_le_q", "sum_q", "sum_p",
                 "fp_q_before_p", "fp_p_before_q")


def _cells(n, m, hi=20):
    return jnp.asarray(RNG.integers(0, hi, (n, m)), jnp.int32)


def _clock(row, k=3):
    return bc.BloomClock(jnp.asarray(row, jnp.int32),
                         jnp.zeros((), jnp.int32), k)


def _assert_bits(got, ref, keys):
    for k in keys:
        assert (np.asarray(got[k]) == np.asarray(ref[k])).all(), k


# ---------------------------------------------------------------------------
# typed pairwise compare + shims
# ---------------------------------------------------------------------------

def test_compare_typed_matches_ordering():
    a, b = _clock(_cells(1, 64)[0]), _clock(_cells(1, 64)[0])
    c = causal.compare(a, b)
    o = bc.ordering(a, b)
    assert bool(c.before()) == bool(o.a_le_b)
    assert bool(c.after()) == bool(o.b_le_a)
    assert bool(c.concurrent()) == bool(o.concurrent)
    assert bool(c.equal()) == bool(o.equal)
    assert float(c.fp_ab) == float(o.fp_a_before_b)
    assert float(c.fp_ba) == float(o.fp_b_before_a)


def test_clock_compare_shim_warns_and_delegates():
    a, b = _clock(_cells(1, 64)[0]), _clock(_cells(1, 64)[0])
    with pytest.warns(DeprecationWarning, match="clock.compare is deprecated"):
        o = bc.compare(a, b)
    ref = bc.ordering(a, b)
    assert bool(o.a_le_b) == bool(ref.a_le_b)
    assert float(o.fp_a_before_b) == float(ref.fp_a_before_b)


def test_ops_shims_warn_and_are_bit_identical():
    cells = _cells(9, 100)
    u8, base, ok = pack.pack_rows(cells)
    assert bool(ok.all())
    eng = causal.CausalEngine()
    got = eng.pairs(causal.PackedSlab(u8, base))
    with pytest.warns(DeprecationWarning, match="compare_matrix_packed"):
        ref = ops.compare_matrix_packed(u8, base)
    _assert_bits(got, ref, MATRIX_KEYS)
    cres = eng.classify(cells[0], cells)
    with pytest.warns(DeprecationWarning, match="classify_vs_many"):
        cref = ops.classify_vs_many(cells[0], cells)
    _assert_bits(cres, cref, CLASSIFY_KEYS)


# ---------------------------------------------------------------------------
# engine paths vs shims: i32 fallback, packed tri, mxu, forced i32
# ---------------------------------------------------------------------------

def test_pairs_auto_pack_matches_shim():
    cells = _cells(13, 129, hi=9)          # span fits a byte -> packed tri
    got = causal.CausalEngine().pairs(cells)
    assert got.engine == "tri"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ref = ops.compare_matrix(cells, cells)
    _assert_bits(got, ref, MATRIX_KEYS)


def test_pairs_wide_span_i32_fallback_matches_shim():
    cells = _cells(7, 65, hi=5).at[0, 0].set(100000)   # span > U8_MAX
    got = causal.CausalEngine().pairs(cells)
    assert got.engine == "i32"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ref = ops.compare_matrix(cells, cells)
    _assert_bits(got, ref, MATRIX_KEYS)


@pytest.mark.parametrize("engine", ["tri", "mxu", "full"])
def test_pairs_forced_packed_engines_match_shim(engine):
    resid = jnp.asarray(RNG.integers(0, 9, (12, 200)), jnp.int32)
    bases = jnp.asarray(RNG.integers(0, 5, (12,)), jnp.int32)
    u8, pb, ok = pack.pack_rows(resid, bases)
    assert bool(ok.all())
    got = causal.CausalEngine(causal.CausalPolicy(engine=engine)).pairs(
        causal.PackedSlab(u8, pb))
    assert got.engine == engine
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ref = ops.compare_matrix_packed(u8, pb, engine=engine)
    _assert_bits(got, ref, MATRIX_KEYS)


def test_pairs_policy_pack_off_forces_i32():
    cells = _cells(6, 64, hi=9)
    got = causal.CausalEngine(causal.CausalPolicy(pack=False)).pairs(cells)
    assert got.engine == "i32"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ref = ops.compare_matrix(cells, cells, engine="i32")
    _assert_bits(got, ref, MATRIX_KEYS)


def test_pairs_wide_slab_without_base_host():
    """base_host is an optional perf hint: the promoted-row host path
    must work (device uniform-base probe) when it is absent, and match
    the base_host-carrying call bit for bit."""
    cells = _cells(6, 80, hi=9)
    u8, base, _ = pack.pack_rows(cells)
    wide_row = np.zeros(80, np.int64)
    wide_row[2] = 7000
    eng = causal.CausalEngine()
    got = eng.pairs(causal.PackedSlab(u8, base, wide={4: wide_row}))
    ref = eng.pairs(causal.PackedSlab(u8, base, base_host=np.asarray(base),
                                      wide={4: wide_row}))
    assert got.engine.endswith("+wide_rim")
    _assert_bits(got, ref, MATRIX_KEYS)
    # promoted row's true values drive the verdicts
    assert bool(got["row_sums"][4] == 7000.0)


def test_classify_wide_overlay_matches_shim_composition():
    """PackedSlab with a promoted row: the front-door's overlay equals
    the shim composition (packed bulk + overlay_wide_classify)."""
    cells = _cells(8, 96, hi=9)
    u8, base, _ = pack.pack_rows(cells)
    wide_row = np.zeros(96, np.int64)
    wide_row[5] = 4000
    slab = causal.PackedSlab(u8, base, wide={3: wide_row})
    q = cells[0]
    got = causal.CausalEngine().classify(q, slab)
    assert got.engine.endswith("+wide_overlay")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ref = ops.classify_vs_many_packed(q, u8, base)
        ref = ops.overlay_wide_classify(
            ref, q, [3], jnp.asarray(wide_row[None]))
    _assert_bits(got, ref, CLASSIFY_KEYS)


# ---------------------------------------------------------------------------
# sharded paths: bit-identity for shard counts {1, 2, 3, 4, 8}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [1, 2, 3, 4, 8])
def test_engine_sharded_bit_identical(host_devices, shards):
    """Front-door classify/pairs over a mesh-sharded slab vs unsharded.

    shards=3 pins the odd-d path of the HALVED ppermute ring (every
    visiting offset ships its mirror block back transposed); even
    counts pin the self-mirrored half-way step."""
    cap, m = 24, 160
    cells = _cells(cap, m, hi=9)
    u8, base, ok = pack.pack_rows(cells)
    assert bool(ok.all())
    bh = np.asarray(base)
    q = cells[1]
    ref_eng = causal.CausalEngine()
    ref_cls = jax.device_get(
        ref_eng.classify(q, causal.PackedSlab(u8, base, base_host=bh)))
    ref_pairs = jax.device_get(
        ref_eng.pairs(causal.PackedSlab(u8, base, base_host=bh)))
    mesh = make_fleet_mesh(shards)
    eng = causal.CausalEngine(causal.CausalPolicy(mesh=mesh))
    slab = causal.PackedSlab(u8, base, base_host=bh)
    got_cls = jax.device_get(eng.classify(q, slab))
    _assert_bits(got_cls, ref_cls, CLASSIFY_KEYS)
    got_pairs = jax.device_get(eng.pairs(slab))
    _assert_bits(got_pairs, ref_pairs, MATRIX_KEYS)
    if shards > 1:
        assert got_pairs.engine.startswith("ring_full")


def test_registry_odd_shard_count_bit_identical(host_devices):
    """End-to-end registry equivalence on a 3-shard mesh, dead slots and
    a promoted row included."""
    cap, m, k = 12, 96, 3
    peers = {f"p{i}": _clock(RNG.integers(0, 9, m) + 100 * (i % 2), k)
             for i in range(cap)}
    wide = np.zeros(m, np.int64)
    wide[7] = 3000
    peers["p5"] = _clock(wide, k)
    local = bc.merge(peers["p0"], peers["p1"])

    def build(mesh):
        reg = ClockRegistry(capacity=cap, m=m, k=k, mesh=mesh)
        reg.admit_many(peers)
        reg.evict_many(["p2", "p9"])
        return reg

    ref_reg, got_reg = build(None), build(make_fleet_mesh(3))
    ref_v, got_v = ref_reg.classify_all(local), got_reg.classify_all(local)
    np.testing.assert_array_equal(got_v.status, ref_v.status)
    assert (got_v.fp == ref_v.fp).all() and (got_v.sums == ref_v.sums).all()
    _assert_bits(jax.device_get(got_reg.all_pairs()),
                 jax.device_get(ref_reg.all_pairs()), MATRIX_KEYS)


# ---------------------------------------------------------------------------
# pytree round-trips: jit / vmap / device_put onto a sharded mesh
# ---------------------------------------------------------------------------

def test_comparison_jit_vmap_roundtrip():
    cells_a = _cells(6, 48)
    cells_b = _cells(6, 48)
    a = bc.BloomClock(cells_a, jnp.zeros((6,), jnp.int32), 3)
    b = bc.BloomClock(cells_b, jnp.zeros((6,), jnp.int32), 3)

    # identity through jit preserves class, values, and accessors
    c = causal.compare(_clock(cells_a[0]), _clock(cells_b[0]))
    cj = jax.jit(lambda x: x)(c)
    assert isinstance(cj, causal.Comparison)
    leaves_ref, treedef = jax.tree_util.tree_flatten(c)
    assert jax.tree_util.tree_structure(cj) == treedef
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves_ref)
    assert bool(rebuilt.confident(0.5)) == bool(c.confident(0.5))

    # vmap over batched clocks == python loop over rows
    vm = jax.vmap(causal.compare)(a, b)
    assert isinstance(vm, causal.Comparison)
    for i in range(6):
        one = causal.compare(_clock(cells_a[i]), _clock(cells_b[i]))
        assert bool(vm.before()[i]) == bool(one.before())
        assert float(vm.fp_ab[i]) == float(one.fp_ab)

    # the confident gate composes under jit with a static threshold
    gated = jax.jit(lambda r: r.confident(1e-3))(vm)
    np.testing.assert_array_equal(
        np.asarray(gated), np.asarray(vm.confident(1e-3)))


def test_classify_result_jit_roundtrip():
    cells = _cells(10, 64)
    res = causal.CausalEngine().classify(cells[0], cells)
    rj = jax.jit(lambda x: x)(res)
    assert isinstance(rj, causal.ClassifyResult)
    _assert_bits(jax.device_get(rj), jax.device_get(res), CLASSIFY_KEYS)
    np.testing.assert_array_equal(
        np.asarray(rj.confident(1e-4)), np.asarray(res.confident(1e-4)))


def test_comparison_matrix_device_put_sharded(host_devices):
    """ComparisonMatrix leaves survive device_put onto a sharded mesh
    with per-rank NamedShardings — flatten/unflatten keeps the class,
    metadata, and every bit."""
    mesh = make_fleet_mesh(4)
    res = causal.CausalEngine().pairs(_cells(16, 64, hi=9))
    shardings = jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, P("fleet", None) if leaf.ndim == 2 else P("fleet")), res)
    put = jax.device_put(res, shardings)
    assert isinstance(put, causal.ComparisonMatrix)
    assert put.engine == res.engine
    assert put.le.sharding.is_equivalent_to(
        NamedSharding(mesh, P("fleet", None)), put.le.ndim)
    _assert_bits(jax.device_get(put), jax.device_get(res), MATRIX_KEYS)
    # accessors still compose under jit on the sharded pytree
    conf = jax.jit(lambda r: r.confident(0.5))(put)
    np.testing.assert_array_equal(np.asarray(conf),
                                  np.asarray(res.confident(0.5)))


def test_mapping_protocol_and_unknown_key():
    res = causal.CausalEngine().pairs(_cells(5, 64, hi=9))
    assert set(res.keys()) == set(MATRIX_KEYS)
    assert dict(res.items()).keys() == set(MATRIX_KEYS)
    with pytest.raises(KeyError):
        res["nope"]


# ---------------------------------------------------------------------------
# confident(t) ≡ happened_before(a, b, threshold=t)
# ---------------------------------------------------------------------------

def test_confident_equiv_happened_before_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    m = 32

    @settings(max_examples=30, deadline=None)
    @given(data=st.data())
    def check(data):
        a_row = data.draw(st.lists(st.integers(0, 6), min_size=m,
                                   max_size=m))
        if data.draw(st.booleans()):
            # force dominance half the time so both gate branches fire
            inc = data.draw(st.lists(st.integers(0, 3), min_size=m,
                                     max_size=m))
            b_row = [x + d for x, d in zip(a_row, inc)]
        else:
            b_row = data.draw(st.lists(st.integers(0, 6), min_size=m,
                                       max_size=m))
        t = data.draw(st.sampled_from([1e-6, 1e-4, 1e-2, 0.5, 0.99]))
        a, b = _clock(a_row), _clock(b_row)
        got = bool(causal.compare(a, b).confident(t))
        ref = bool(bc.happened_before(a, b, threshold=t))
        assert got == ref

    check()


# ---------------------------------------------------------------------------
# no internal caller routes through the shims
# ---------------------------------------------------------------------------

def test_internal_callers_are_shim_free(tmp_path):
    """The in-test version of the CI deprecation gate: DeprecationWarning
    raised FROM a repro.* module becomes an error, then the fleet /
    runtime / gossip hot paths all run — promoted rows and dead slots
    included, so the overlay + rim dispatch is exercised too."""
    m, k = 96, 3
    reg = ClockRegistry(capacity=8, m=m, k=k)
    rows = {f"p{i}": _clock(RNG.integers(0, 9, m), k) for i in range(6)}
    wide = np.zeros(m, np.int64)
    wide[0] = 5000
    rows["wide"] = _clock(wide, k)
    reg.admit_many(rows)
    reg.evict("p4")
    local = bc.merge(rows["p0"], rows["p1"])
    rt = ClockRuntime(ClockConfig(m=m, k=k))
    rt.clock = local
    with warnings.catch_warnings():
        warnings.simplefilter("always")
        warnings.filterwarnings("error", category=DeprecationWarning,
                                module=r"repro\..*")
        reg.classify_all(local)
        reg.all_pairs()
        gossip_round(reg, local)
        fleet_health(reg)
        rt.classify_fleet(reg)
        rt.admit_merge(rows["p2"])
        causal.CausalEngine().pairs(_cells(5, m, hi=9))


# ---------------------------------------------------------------------------
# policy threading
# ---------------------------------------------------------------------------

def test_policy_is_single_source_of_truth():
    pol = causal.CausalPolicy(fp_threshold=0.5, engine="i32", pack=False)
    rt = ClockRuntime(ClockConfig(m=64, k=3, policy=pol))
    assert rt.policy is pol and rt.causal.policy is pol
    reg = rt.make_registry(8)
    assert reg.policy.fp_threshold == 0.5
    assert reg.policy.engine == "i32"
    # GossipConfig: the policy's threshold wins over the legacy scalar,
    # and explicit use of the scalar warns (deliberate shim exercise)
    with pytest.warns(DeprecationWarning, match="fp_threshold is deprecated"):
        cfg = GossipConfig(fp_threshold=1e-9, policy=pol)
    assert cfg.fp_gate == 0.5
    with pytest.warns(DeprecationWarning, match="fp_threshold is deprecated"):
        legacy = GossipConfig(fp_threshold=1e-9)
    assert legacy.fp_gate == 1e-9
    assert GossipConfig().fp_gate == 1e-4    # default: no warning, old gate


def test_gossip_policy_equivalent_to_scalar_threshold():
    m, k = 64, 3
    rows = {f"p{i}": _clock(RNG.integers(0, 9, m), k) for i in range(6)}
    local = bc.merge(rows["p0"], rows["p1"])

    def run(cfg):
        reg = ClockRegistry(capacity=8, m=m, k=k)
        reg.admit_many(rows)
        return gossip_round(reg, local, cfg)[1]

    with pytest.warns(DeprecationWarning, match="fp_threshold is deprecated"):
        legacy_cfg = GossipConfig(fp_threshold=0.9)
    a = run(legacy_cfg)
    b = run(GossipConfig(policy=causal.CausalPolicy(fp_threshold=0.9)))
    np.testing.assert_array_equal(a.accepted, b.accepted)
    np.testing.assert_array_equal(a.unconfident, b.unconfident)
    np.testing.assert_array_equal(a.quarantined, b.quarantined)


def test_policy_validation_and_labels():
    with pytest.raises(ValueError, match="unknown engine"):
        causal.CausalPolicy(engine="warp")
    lab = causal.CausalPolicy(engine="mxu", bi=8, autotune=False).label()
    assert "engine=mxu" in lab and "bi8" in lab and "autotune=off" in lab
    merged = causal.CausalPolicy().merged(engine="tri", bm=256)
    assert merged.engine == "tri" and merged.bm == 256
    assert causal.CausalPolicy().merged() == causal.CausalPolicy()
