"""Tiered registry invariants.

The contract under test: a ``TieredRegistry`` is an OPTIMIZATION, not a
semantic — ``classify`` must be bit-identical (status flags AND Eq. 3
fp floats) to one flat oversized ``ClockRegistry`` holding the same
sessions, no matter how admit/release/touch/promote/demote/evict churn
has scattered them across hot/warm/cold, and including int32-rim
(near-wrap promoted) rows crossing tiers.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clock as bc
from repro.fleet.registry import INT32_MAX, STATUS_NAMES, ClockRegistry
from repro.serve.tiers import TierConfig, TieredRegistry

M, K = 32, 3

SMALL = TierConfig(hot_capacity=6, warm_capacity=10, promote_after=2,
                   demote_batch=2, spill_batch=4, cold_batch=4)


def _clock(rng, hi=6, base=0):
    cells = jnp.asarray(rng.integers(0, hi, M), jnp.int32)
    c = bc.BloomClock(cells=cells, base=jnp.zeros((), jnp.int32), k=K)
    if base:
        c = bc.BloomClock(cells=c.cells + jnp.int32(base),
                          base=jnp.zeros((), jnp.int32), k=K)
    return bc.compress(c)


def _flat_of(tiered: TieredRegistry, clocks: dict) -> ClockRegistry:
    """The reference: same sessions, one slab, SAME pinned policy (the
    tiered registry pins its kernel blocks at flat-equivalent capacity,
    so the flat slab must classify with the same blocks)."""
    flat = ClockRegistry(capacity=max(8, 2 * len(clocks) + 4), m=M, k=K,
                         policy=tiered.policy)
    flat.admit_many(clocks)
    return flat


def _assert_bit_identical(tiered, clocks, query, msg=""):
    view = tiered.classify(query)
    flat = _flat_of(tiered, clocks)
    ref = flat.classify_all(query)
    for sid in clocks:
        slot = flat.slot_of(sid)
        assert view.verdict_of(sid) == STATUS_NAMES[int(ref.status[slot])], \
            f"{msg} verdict drift for {sid} ({tiered._tier_of.get(sid)})"
        got, want = view.fp_of(sid), float(ref.fp[slot])
        assert got == want, \
            f"{msg} fp drift for {sid}: {got!r} != {want!r}"


def test_three_tier_spread_bit_identical():
    rng = np.random.default_rng(0)
    t = TieredRegistry(SMALL, m=M, k=K)
    clocks = {f"s{i}": _clock(rng) for i in range(30)}
    t.admit_many(clocks)
    tiers_used = set(t._tier_of.values())
    assert tiers_used == {"hot", "warm", "cold"}
    q = bc.BloomClock(cells=jnp.full((M,), 9, jnp.int32),
                      base=jnp.zeros((), jnp.int32), k=K)
    _assert_bit_identical(t, clocks, q, "spread")
    t.close()


def test_promotion_crosses_tiers_bit_identical():
    rng = np.random.default_rng(1)
    t = TieredRegistry(SMALL, m=M, k=K)
    clocks = {f"s{i}": _clock(rng) for i in range(24)}
    t.admit_many(clocks)
    cold_sid = next(s for s, tier in t._tier_of.items() if tier == "cold")
    for _ in range(SMALL.promote_after):
        t.touch(cold_sid)
    assert t._tier_of[cold_sid] == "hot"
    q = _clock(rng, hi=12)
    _assert_bit_identical(t, clocks, q, "promotion")
    t.close()


def test_near_wrap_rows_cross_tiers_bit_identical():
    """int32-rim sessions (base pushed against INT32_MAX, the PR-8
    promoted-row representation) must survive hot→warm→cold demotion
    and classify identically from every tier."""
    rng = np.random.default_rng(2)
    t = TieredRegistry(SMALL, m=M, k=K)
    rim_base = INT32_MAX - 40
    clocks = {f"rim{i}": _clock(rng, hi=5, base=rim_base) for i in range(4)}
    clocks.update({f"s{i}": _clock(rng) for i in range(20)})
    # admit rims FIRST: the later flood demotes them through the tiers
    t.admit_many({s: clocks[s] for s in clocks if s.startswith("rim")})
    t.admit_many({s: clocks[s] for s in clocks if not s.startswith("rim")})
    rim_tiers = {t._tier_of[f"rim{i}"] for i in range(4)}
    assert rim_tiers - {"hot"}, "flood should have demoted some rim rows"
    q = _clock(rng, hi=5, base=rim_base + 20)
    _assert_bit_identical(t, clocks, q, "near-wrap")
    t.close()


def test_release_and_targeted_classify():
    rng = np.random.default_rng(3)
    t = TieredRegistry(SMALL, m=M, k=K)
    clocks = {f"s{i}": _clock(rng) for i in range(18)}
    t.admit_many(clocks)
    victims = ["s0", "s7", "s17"]
    for sid in victims:
        t.release(sid)
        del clocks[sid]
    assert all(sid not in t for sid in victims)
    q = bc.BloomClock(cells=jnp.full((M,), 7, jnp.int32),
                      base=jnp.zeros((), jnp.int32), k=K)
    want = list(clocks)[:5]
    view = t.classify(q, sids=want)
    flat = _flat_of(t, clocks)
    ref = flat.classify_all(q)
    for sid in want:
        slot = flat.slot_of(sid)
        assert view.verdict_of(sid) == STATUS_NAMES[int(ref.status[slot])]
        assert view.fp_of(sid) == float(ref.fp[slot])
    t.close()


def test_get_roundtrip_exact_across_tiers():
    rng = np.random.default_rng(4)
    t = TieredRegistry(SMALL, m=M, k=K)
    clocks = {f"s{i}": _clock(rng) for i in range(26)}
    clocks["rim"] = _clock(rng, hi=4, base=INT32_MAX - 9)
    t.admit_many(clocks)
    for sid, want in clocks.items():
        got = t.get(sid, count=False)
        np.testing.assert_array_equal(
            np.asarray(got.logical_cells()),
            np.asarray(want.logical_cells()),
            err_msg=f"{sid} ({t._tier_of[sid]})")
    t.close()


# ---------------------------------------------------------------------------
# interleaved operation sequences: hypothesis when available, plus a
# seeded deterministic fallback so the property is exercised everywhere
# ---------------------------------------------------------------------------
def _run_interleaved(ops, seed):
    """Any interleaving of admit / release / touch (touch triggers
    promotion, admits trigger demotion + spill) leaves classify
    bit-identical to the flat slab — flags and Eq. 3 fp."""
    rng = np.random.default_rng(seed)
    t = TieredRegistry(SMALL, m=M, k=K)
    clocks = {}
    for op, n, rim in ops:
        sid = f"s{n}"
        if op == "admit":
            c = _clock(rng, hi=5,
                       base=INT32_MAX - int(rng.integers(5, 60))
                       if rim else 0)
            clocks[sid] = c
            t.admit(sid, c)
        elif op == "release" and sid in clocks:
            t.release(sid)
            del clocks[sid]
        elif op == "touch" and sid in clocks:
            t.touch(sid)
    if clocks:
        q = _clock(rng, hi=10)
        _assert_bit_identical(t, clocks, q, "interleaved")
    t.close()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_interleaved_ops_seeded(seed):
    rng = np.random.default_rng(1000 + seed)
    ops = [(["admit", "release", "touch"][int(rng.integers(0, 3))],
            int(rng.integers(0, 40)), bool(rng.integers(0, 4) == 0))
           for _ in range(50)]
    _run_interleaved(ops, seed)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised via the seeded variant
    pass
else:
    _ops = st.lists(
        st.one_of(
            st.tuples(st.just("admit"), st.integers(0, 39),
                      st.booleans()),       # (op, sid#, near_wrap_row?)
            st.tuples(st.just("release"), st.integers(0, 39),
                      st.just(False)),
            st.tuples(st.just("touch"), st.integers(0, 39), st.just(False)),
        ),
        min_size=5, max_size=60)

    @settings(max_examples=20, deadline=None)
    @given(ops=_ops, seed=st.integers(0, 2**16))
    def test_interleaved_ops_keep_flat_equivalence(ops, seed):
        _run_interleaved(ops, seed)
