"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels run with interpret=True on CPU (the kernel BODY executes in
Python), asserting exact equality for integer ops and allclose for the
fp-rate math.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hashing import bloom_indices
from repro.kernels import ops, ref
from repro.kernels.bloom_tick import bloom_tick_pallas
from repro.kernels.bloom_compare import bloom_merge_compare_pallas

RNG = np.random.default_rng(0)


def _events(B, E):
    hi = RNG.integers(0, 2**32, (B, E), dtype=np.uint64).astype(np.uint32)
    lo = RNG.integers(0, 2**32, (B, E), dtype=np.uint64).astype(np.uint32)
    return jnp.asarray(hi), jnp.asarray(lo)


@pytest.mark.parametrize("B,m,E,k", [
    (1, 128, 1, 1),
    (3, 300, 7, 3),       # non-aligned m/B
    (8, 512, 16, 4),      # aligned
    (5, 64, 2, 8),        # k > E
    (16, 2048, 32, 2),    # multi m-tile
])
def test_tick_matches_ref(B, m, E, k):
    cells = jnp.asarray(RNG.integers(0, 100, (B, m)), jnp.int32)
    hi, lo = _events(B, E)
    out = ops.tick(cells, hi, lo, k=k)
    probes = bloom_indices(hi, lo, k, m).reshape(B, -1).astype(jnp.int32)
    expect = ref.bloom_tick_ref(cells, probes)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    # total increments conserved
    assert int(jnp.sum(out) - jnp.sum(cells)) == B * E * k


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.uint16, jnp.int16])
def test_tick_dtypes(dtype):
    B, m, E, k = 4, 256, 3, 4
    cells = jnp.asarray(RNG.integers(0, 50, (B, m)), dtype)
    hi, lo = _events(B, E)
    out = ops.tick(cells, hi, lo, k=k)
    probes = bloom_indices(hi, lo, k, m).reshape(B, -1).astype(jnp.int32)
    expect = ref.bloom_tick_ref(cells, probes)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("B,m", [
    (1, 128), (3, 300), (8, 512), (16, 2048), (7, 64),
])
def test_merge_compare_matches_ref(B, m):
    a = jnp.asarray(RNG.integers(0, 20, (B, m)), jnp.int32)
    b = jnp.asarray(RNG.integers(0, 20, (B, m)), jnp.int32)
    # force some ordered rows
    b = b.at[0].set(a[0])                       # equal
    if B > 1:
        b = b.at[1].set(a[1] + 1)               # strictly dominated
    got = ops.merge_compare(a, b)
    merged, flags, sums, fp = ref.bloom_merge_compare_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got["merged"]), np.asarray(merged))
    np.testing.assert_array_equal(np.asarray(got["a_le_b"]),
                                  np.asarray(flags[:, 0]).astype(bool))
    np.testing.assert_array_equal(np.asarray(got["b_le_a"]),
                                  np.asarray(flags[:, 1]).astype(bool))
    np.testing.assert_allclose(np.asarray(got["sum_a"]), np.asarray(sums[:, 0]))
    np.testing.assert_allclose(np.asarray(got["fp_a_before_b"]),
                               np.asarray(fp[:, 0]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got["fp_b_before_a"]),
                               np.asarray(fp[:, 1]), rtol=1e-5)


def test_merge_compare_consistent_with_core_clock():
    """Kernel path and repro.core.clock agree on a simulated pair."""
    from repro.core import clock as bc

    m, k = 256, 4
    a = bc.zeros(m, k)
    for i in range(10):
        a = bc.tick(a, jnp.uint32(0), jnp.uint32(i))
    b = a
    for i in range(5):
        b = bc.tick(b, jnp.uint32(0), jnp.uint32(100 + i))
    got = ops.merge_compare(a.cells[None], b.cells[None])
    o = bc.ordering(a, b)
    assert bool(got["a_le_b"][0]) == bool(o.a_le_b)
    np.testing.assert_allclose(float(got["fp_a_before_b"][0]),
                               float(o.fp_a_before_b), rtol=1e-5)


def test_tick_kernel_direct_padding_free():
    """Exercise the raw pallas_call on aligned shapes (no wrapper pads)."""
    B, m, P = 8, 1024, 64
    cells = jnp.asarray(RNG.integers(0, 9, (B, m)), jnp.int32)
    probes = jnp.asarray(RNG.integers(0, m, (B, P)), jnp.int32)
    out = bloom_tick_pallas(cells, probes, bb=8, bm=256, interpret=True)
    expect = ref.bloom_tick_ref(cells, probes)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_compare_kernel_multi_tile_accumulation():
    """Dominance/sums must accumulate correctly across m-tiles."""
    B, m = 8, 1024
    a = jnp.zeros((B, m), jnp.int32)
    b = jnp.zeros((B, m), jnp.int32)
    # violate dominance ONLY in the last tile: catches bad accumulation
    a = a.at[:, -1].set(5)
    got = bloom_merge_compare_pallas(a, b, bb=8, bm=128, interpret=True)
    _, flags, sums, _ = got
    assert not bool(flags[0, 0])     # a <= b is false (last tile)
    assert bool(flags[0, 1])         # b <= a holds
    assert float(sums[0, 0]) == 5.0
