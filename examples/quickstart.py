"""Quickstart: the bloom clock as a library, in five minutes.

Run:  python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp

from repro import causal
from repro.core import clock as bc
from repro.core.hashing import stable_event_id
from repro.kernels import ops


def ev(*parts):
    hi, lo = stable_event_id(*parts)
    return jnp.uint32(hi), jnp.uint32(lo)


def main():
    # two nodes, each with a 64-cell clock, 4 hash probes per event
    a = bc.zeros(m=64, k=4)
    b = bc.zeros(m=64, k=4)

    # node A records three local events
    for i in range(3):
        a = bc.tick(a, *ev("A", i))

    # A broadcasts; B receives -> element-wise max (paper §3 step 3)
    b = bc.merge(b, a)
    # B records its own event
    b = bc.tick(b, *ev("B", 0))

    # the public causality API: typed results + the uniform Eq. 3 gate
    o = causal.compare(a, b)
    print(f"A -> B?   {bool(o.before())}  (fp rate {float(o.fp_ab):.4f})")
    print(f"B -> A?   {bool(o.after())}")
    print(f"confident at 1e-3? {bool(o.confident(1e-3))}")
    print(f"concurrent? {bool(o.concurrent())}  (exact — no false negatives)")

    # now a third node C that never talked to anyone
    c = bc.tick(bc.zeros(64, 4), *ev("C", 0))
    print(f"A vs C concurrent? {bool(causal.compare(a, c).concurrent())}")

    # paper §4 compression: (base)[residuals]
    for i in range(200):
        b = bc.tick(b, *ev("B", i + 1))
    z = bc.compress(b)
    print(f"compressed: base={int(z.base)}, max residual={int(jnp.max(z.cells))} "
          f"(vs raw max {int(jnp.max(b.logical_cells()))})")

    # the TPU kernel path (interpret=True on CPU): batched receive
    batch_a = jnp.tile(a.cells[None], (8, 1))
    batch_b = jnp.tile(b.logical_cells()[None], (8, 1))
    out = ops.merge_compare(batch_a, batch_b)
    print(f"kernel fused merge+compare over batch of 8: "
          f"a_le_b={out['a_le_b'].tolist()}")

    # bulk comparisons go through the CausalEngine front-door: one
    # dispatch surface over every Pallas engine (packed u8 / MXU / i32)
    engine = causal.CausalEngine(causal.CausalPolicy(fp_threshold=1e-3))
    clocks = jnp.stack([a.logical_cells(), b.logical_cells(),
                        c.logical_cells()])
    mats = engine.pairs(clocks)                  # all-pairs, one call
    print(f"pairs (engine={mats.engine}): concurrent=\n"
          f"{mats.concurrent().astype(int)}")
    res = engine.classify(a, clocks)             # one-vs-many, one call
    print(f"classify A vs [A,B,C]: before={res.before().tolist()} "
          f"confident={res.confident(1e-3).tolist()}")

    # the paper's worked fp example: m=6, ΣB=10, ΣA=7 -> 0.29
    print(f"Eq.3 paper example: {float(bc.fp_rate(7, 10, 6)):.2f} (paper: 0.29)")


if __name__ == "__main__":
    main()
