"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
clock-stamped checkpoints and a mid-run failure + verified restart.

This is the deliverable-(b) end-to-end example.  ~100M params on CPU is
slow but real; pass --small for a quick demo.

Run:  PYTHONPATH=src python examples/train_100m.py [--small]
"""
import argparse
import dataclasses
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core import clock as bc
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.config import ModelConfig
from repro.optim.adamw import OptConfig
from repro.runtime.clock_runtime import ClockConfig, ClockRuntime
from repro.runtime.training import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="5M params / 60 steps instead of ~100M / 300")
    args = ap.parse_args()

    if args.small:
        cfg = ModelConfig(name="demo-5m", n_layers=4, d_model=128, n_heads=4,
                          n_kv_heads=4, d_head=32, d_ff=512, vocab=8192,
                          attn_chunk=128)
        steps, seq, batch = 60, 128, 8
    else:
        # ~100M dense LM (GPT-2-small-ish, llama-style blocks)
        cfg = ModelConfig(name="demo-100m", n_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=12, d_head=64, d_ff=2048,
                          vocab=32768, attn_chunk=256)
        steps, seq, batch = 300, 256, 8
    print(f"[example] {cfg.name}: {cfg.n_params()/1e6:.1f}M params")

    opt_cfg = OptConfig(lr=3e-3, total_steps=steps, warmup_steps=steps // 20)
    clock_cfg = ClockConfig()
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch, run_id="ex100m"))
    runtime = ClockRuntime(clock_cfg, run_id="ex100m")
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ex_")
    mgr = CheckpointManager(ckpt_dir, run_id="ex100m")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, clock_cfg))
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg, clock_cfg)

    ckpt_every = max(10, steps // 6)
    fail_at = ckpt_every + ckpt_every // 2  # after the first checkpoint
    step = 0
    restarted = False
    while step < steps:
        b = data.batch(step)
        hi, lo = data.event_id(step)
        b["ev_hi"], b["ev_lo"] = jnp.uint32(hi), jnp.uint32(lo)
        runtime.tick_batch(step)
        state, metrics = step_fn(state, b)
        runtime.tick_step(step)
        if step % 20 == 0:
            print(f"[example] step={step:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e}")
        step += 1
        if step % ckpt_every == 0:
            runtime.tick_checkpoint(step)
            mgr.save(step, state, runtime.snapshot(), block=True)
        if step == fail_at and not restarted:
            print(f"[example] *** simulated preemption at step {step} ***")
            restarted = True
            # new process: fresh runtime, restore from latest checkpoint
            runtime = ClockRuntime(clock_cfg, run_id="ex100m")
            restored, manifest = mgr.restore(target_structure=state)
            ck = ClockRuntime.clock_from_snapshot(manifest["clock"])
            ok, status, fp = runtime.admit_restore(ck)
            print(f"[example] restore step={manifest['step']} lineage={status} "
                  f"admitted={ok}")
            assert ok
            state = restored
            runtime.clock = bc.merge(runtime.clock, ck)
            step = manifest["step"]
    print(f"[example] done. final loss ~{float(metrics['loss']):.4f}; "
          f"clock sum {float(bc.clock_sum(runtime.clock)):.0f}")


if __name__ == "__main__":
    main()
