"""Serving example: batched generation + clock-gated session migration.

Two replicas serve the same model.  A session admitted on replica A
migrates to replica B (which shares causal history -> accepted) and is
refused by replica C (which doesn't -> stale-read prevented).

Run:  PYTHONPATH=src python examples/serve_sessions.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.core import clock as bc
from repro.models.config import ModelConfig
from repro.models.params import init_params
from repro.causal import CausalPolicy
from repro.runtime.clock_runtime import ClockConfig
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    cfg = ModelConfig(name="serve-demo", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=2, d_head=32, d_ff=256, vocab=4096,
                      dtype="float32", attn_chunk=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    s_cfg = ServeConfig(max_batch=4, max_seq=96)
    c_cfg = ClockConfig(m=512, policy=CausalPolicy(fp_threshold=0.999999))

    rep_a = ServingEngine(params, cfg, s_cfg, c_cfg, replica_id="A")
    rep_b = ServingEngine(params, cfg, s_cfg, c_cfg, replica_id="B")
    rep_c = ServingEngine(params, cfg, s_cfg, c_cfg, replica_id="C")

    # keep B in the same gossip domain as A
    rep_b.clock.clock = bc.merge(rep_b.clock.clock, rep_a.clock.clock)

    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    sess = rep_a.admit(prompts)
    toks = rep_a.generate(sess, 12)
    print(f"[serve] replica A generated: {toks.shape} "
          f"first row: {toks[0].tolist()}")

    # gossip A -> B (replicas exchange clocks out-of-band, O(m) each)
    rep_b.clock.clock = bc.merge(rep_b.clock.clock, rep_a.clock.clock)
    ok_b, status_b, fp_b = rep_b.can_adopt(sess)
    print(f"[serve] migrate to B: {status_b} fp={fp_b:.2e} -> "
          f"{'ACCEPT' if ok_b else 'REFUSE'}")

    rep_c.clock.tick("own", "history")  # C has its own unrelated history
    ok_c, status_c, _ = rep_c.can_adopt(sess)
    print(f"[serve] migrate to C: {status_c} -> "
          f"{'ACCEPT' if ok_c else 'REFUSE'} (stale-read prevented)")

    assert ok_b and not ok_c


if __name__ == "__main__":
    main()
