"""Async multi-pod training (DiLoCo-style) with clock-guarded merges —
the paper's technique running the show.

Four pods train locally and sync through an outer optimizer.  Mid-run:
pod 2 stalls (straggler), pod 3 restores a stale snapshot and forks.
Watch the coordinator's decisions — made purely from O(m) bloom clocks.

Run:  PYTHONPATH=src python examples/async_pods.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import clock as bc
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.params import init_params
from repro.runtime.async_trainer import (AsyncConfig, AsyncCoordinator,
                                         run_pod_round)
from repro.causal import CausalPolicy
from repro.runtime.clock_runtime import ClockConfig
from repro.runtime.training import cross_entropy


def main():
    cfg = ModelConfig(name="pods-demo", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=4, d_head=32, d_ff=256, vocab=4096,
                      dtype="float32", attn_chunk=64)
    a_cfg = AsyncConfig(n_pods=4, local_steps=4, outer_lr=0.6)
    c_cfg = ClockConfig(m=512, straggler_gap=8.0,
                        policy=CausalPolicy(fp_threshold=1e-4))
    params = init_params(jax.random.PRNGKey(0), cfg)
    coord = AsyncCoordinator(params, a_cfg, c_cfg)
    pods = coord.add_pods(list(range(a_cfg.n_pods)), c_cfg)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4))

    def loss_fn(p, batch):
        logits, _ = T.forward_train(p, cfg, batch["tokens"])
        return cross_entropy(logits, batch["labels"], cfg.vocab)

    @jax.jit
    def sgd_step(p, batch):
        l, g = jax.value_and_grad(loss_fn)(p, batch)
        return jax.tree.map(lambda w, gr: w - 3e-3 * gr, p, g), l

    def data_fn(pod_id, step):
        return data.batch(step * a_cfg.n_pods + pod_id)

    stale = None
    for rnd in range(8):
        deltas = {}
        for pod in pods:
            if rnd == 4 and pod.pod_id == 2:
                # straggler: no work this round
                deltas[pod.pod_id] = jax.tree.map(
                    jnp.zeros_like, coord.params)
                continue
            if rnd == 4 and pod.pod_id == 3:
                # fork: restore the snapshot taken before round 3's commit
                pod.clock.clock = stale
            d, _ = run_pod_round(pod, sgd_step, data_fn, a_cfg, rnd * 100)
            deltas[pod.pod_id] = d
            if rnd == 3 and pod.pod_id == 3:
                stale = pod.clock.clock  # pre-commit snapshot
        decisions = coord.outer_step(pods, deltas)
        loss = float(loss_fn(jax.tree.map(
            lambda x: x.astype(jnp.float32), coord.params), data.batch(999)))
        verdicts = {p: (("MERGED" if ok else f"REJECTED({why})"))
                    for p, (ok, why, _) in decisions.items()}
        print(f"[round {rnd}] eval_loss={loss:.4f} {verdicts}")

    # recover the forked pod: resync to the published union clock
    pods[3].clock.clock = bc.merge(pods[3].clock.clock, coord.clock.clock)
    pods[3].params = dict(coord.params)
    d, _ = run_pod_round(pods[3], sgd_step, data_fn, a_cfg, 900)
    deltas = {3: d}
    for pod in pods[:3]:
        deltas[pod.pod_id], _ = run_pod_round(pod, sgd_step, data_fn, a_cfg, 900)
    decisions = coord.outer_step(pods, deltas)
    print(f"[recovery] pod3 readmitted: {decisions[3][0]}")


if __name__ == "__main__":
    main()
